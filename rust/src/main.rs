//! `ttd` — the timestamp-tokens dataflow launcher.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!
//! ```text
//! ttd wordcount  [--workers N] [--rate R] [--quantum-bits B]
//!                [--mechanism tokens|notifications|watermarks-x]
//!                [--duration-ms D]           the §7.2 microbenchmark
//! ttd noop       [--chain N] [--ticks R] ...  the §7.3 idle pipeline
//! ttd nexmark    [--query q4|q7] [--window-ms W] ...   the §7.4 queries
//! ttd serve      [--workers N] [--epochs E] [--keys K]
//!                                 interactive serving smoke: feeds a
//!                                 deterministic upsert/delete script,
//!                                 verifies every frontier-gated point
//!                                 lookup against a sequential oracle
//!                                 (before and after compaction), and
//!                                 prints p50/p99 lookup latency;
//!                                 nonzero exit on any mismatch
//! ttd artifacts  [--dir PATH]                 verify the PJRT data plane
//! ttd info                                    engine / environment info
//! ttd trace-check --file out.json [--expect-workers N]
//!                                 validate a --trace output file
//! ttd recovery-demo [--workload wordcount|q4] [--epochs N]
//!                [--checkpoint-dir D] [--checkpoint-interval E]
//!                [--recover D] [--kill-process P --kill-after-ms M]
//!                                 deterministic crash/recovery workload
//! ```
//!
//! `recovery-demo` feeds a deterministic word stream and prints an order-
//! and partition-independent digest of the final counts, so a run that is
//! SIGKILLed mid-flight (`--kill-process`, orchestrator mode only) and
//! then recovered from its checkpoint directory (`--recover D`, possibly
//! with a *different* `--processes`/`--workers` shape) can be checked for
//! exact equality against an unperturbed run. With `--checkpoint-interval
//! E` every worker captures its state at frontier-aligned epoch
//! boundaries; `--recover D` restores the newest complete checkpoint in
//! `D` and replays only the epochs after it.
//!
//! Any workload runs **multi-process** with `--processes N` (`--workers`
//! then counts per-process workers). Without `--process I` the launcher
//! orchestrates: it re-execs itself once per process index and waits —
//! `ttd wordcount --processes 2 --workers 2` is a complete 2×2 cluster on
//! one machine. With `--process I` it runs as cluster member `I`
//! (distributed launches: start the same command on each host).
//! Addresses default to `127.0.0.1:{base-port + i}` (`--base-port`,
//! default 40701) or come from `--addresses host:port,host:port,...`.
//! Process 0's `ring_capacity` / `progress_flush` / `send_batch` flags
//! propagate to every process through the bootstrap handshake.
//! `--net auto|tcp|shm|tcp-threads` selects the cross-process transport
//! (default `auto`: shared memory for co-located loopback process pairs,
//! reactor-driven TCP otherwise); every process must pass the same value.
//! `--reactor auto|poll|epoll` picks the readiness backend (per process;
//! `auto` = epoll on Linux), `--parking auto|doorbell|futex` the
//! shared-memory wake protocol, and `--autotune on` enables the
//! telemetry-driven governor (live shm-ring grows + online
//! progress-flush cadence) — the latter two propagate from process 0
//! like the other tuning knobs.
//!
//! Any workload also takes `--trace out.json` (Chrome trace-event JSON:
//! operator spans, progress/park/checkpoint spans, net instants, and
//! per-epoch frontier-latency summaries — open in Perfetto) and
//! `--metrics out.jsonl` (periodic telemetry snapshots). Both propagate
//! from process 0 over the handshake; in multi-process runs each
//! process writes `out.p<I>.json`. `ttd trace-check` validates a trace
//! file's structure.

use std::time::{Duration, Instant};
use timestamp_tokens::config::{
    Config, NetOptions, NetTransport, ObserveOptions, Parking, ReactorBackend,
};
use timestamp_tokens::coordination::Mechanism;
use timestamp_tokens::harness::openloop::{
    run_cluster_observed, run_observed, Outcome, Params, Workload,
};
use timestamp_tokens::harness::recovery_demo::{
    run_q4_recovery_demo, run_recovery_demo, DemoOutcome, RecoveryDemoParams,
};
use timestamp_tokens::net::NetError;
use timestamp_tokens::harness::report::{latency_cells, print_worker_telemetry};
use timestamp_tokens::nexmark::bench::{
    run_nexmark_cluster_observed, run_nexmark_observed, NexmarkParams, Query,
};
use timestamp_tokens::serve::{serve_worker, ServePlane};
use timestamp_tokens::worker::execute::{execute, execute_cluster};

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(args: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut iter = args.iter();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some(value) = iter.next() {
                    flags.insert(key.to_string(), value.clone());
                }
            }
        }
        Args { flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn mechanism(&self) -> Mechanism {
        self.flags
            .get("mechanism")
            .map(|m| m.parse().expect("tokens|notifications|watermarks-x|watermarks-p"))
            .unwrap_or(Mechanism::Tokens)
    }

    /// The `--trace` / `--metrics` output paths (off by default).
    fn observe(&self) -> ObserveOptions {
        ObserveOptions {
            trace_path: self.flags.get("trace").cloned(),
            metrics_path: self.flags.get("metrics").cloned(),
        }
    }

    /// The cluster topology requested on the command line.
    fn cluster(&self) -> ClusterArgs {
        let processes = self.get("processes", 1usize).max(1);
        let addresses = match self.flags.get("addresses") {
            Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
            None => {
                let base = self.get("base-port", 40701u16);
                (0..processes).map(|i| format!("127.0.0.1:{}", base + i as u16)).collect()
            }
        };
        let transport = self
            .flags
            .get("net")
            .map(|v| v.parse().expect("--net auto|tcp|shm|tcp-threads"))
            .unwrap_or(NetTransport::Auto);
        let reactor = self
            .flags
            .get("reactor")
            .map(|v| v.parse().expect("--reactor auto|poll|epoll"))
            .unwrap_or(ReactorBackend::Auto);
        let parking = self
            .flags
            .get("parking")
            .map(|v| v.parse().expect("--parking auto|doorbell|futex"))
            .unwrap_or(Parking::Auto);
        let autotune = self
            .flags
            .get("autotune")
            .map(|v| matches!(v.as_str(), "on" | "true" | "1"))
            .unwrap_or(false);
        ClusterArgs {
            processes,
            process: self.flags.get("process").and_then(|v| v.parse().ok()),
            addresses,
            net: NetOptions { transport, reactor, parking, autotune },
        }
    }
}

/// Parsed `--processes` / `--process` / `--addresses` / `--net` /
/// `--reactor` / `--parking` / `--autotune` flags.
struct ClusterArgs {
    processes: usize,
    /// `None` = orchestrate (spawn one child per process index).
    process: Option<usize>,
    addresses: Vec<String>,
    net: NetOptions,
}

impl ClusterArgs {
    fn validate(&self) {
        assert_eq!(
            self.addresses.len(),
            self.processes,
            "--addresses must list one host:port per process"
        );
        if let Some(p) = self.process {
            assert!(p < self.processes, "--process {p} out of range 0..{}", self.processes);
        }
    }
}

/// Orchestrator mode: re-exec this binary once per process index with the
/// original arguments plus `--process i`, wait for all, and fail if any
/// child failed.
fn orchestrate(processes: usize) -> ! {
    let exe = std::env::current_exe().expect("current_exe");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut children = Vec::new();
    for i in 0..processes {
        let child = std::process::Command::new(&exe)
            .args(&argv)
            .arg("--process")
            .arg(i.to_string())
            .spawn()
            .unwrap_or_else(|e| panic!("spawn cluster process {i}: {e}"));
        children.push((i, child));
    }
    let mut failed = false;
    for (i, mut child) in children {
        let status = child.wait().expect("wait for cluster process");
        if !status.success() {
            eprintln!("cluster process {i} exited with {status}");
            failed = true;
        }
    }
    std::process::exit(if failed { 1 } else { 0 });
}

/// `recovery-demo` orchestration: like [`orchestrate`], but with piped
/// child stdout (per-process digest lines XOR into the cluster digest),
/// an optional mid-run SIGKILL of one child, and a hard deadline — a
/// survivor still running long after a kill is exactly the hang the
/// typed peer-loss path exists to prevent, and fails the run.
fn orchestrate_recovery_demo(processes: usize, kill: Option<usize>, kill_after_ms: u64) -> ! {
    use std::io::Read as _;
    let exe = std::env::current_exe().expect("current_exe");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut children = Vec::new();
    for i in 0..processes {
        let child = std::process::Command::new(&exe)
            .args(&argv)
            .arg("--process")
            .arg(i.to_string())
            .stdout(std::process::Stdio::piped())
            .spawn()
            .unwrap_or_else(|e| panic!("spawn cluster process {i}: {e}"));
        children.push(child);
    }
    if let Some(victim) = kill {
        assert!(victim < processes, "--kill-process {victim} out of range");
        std::thread::sleep(Duration::from_millis(kill_after_ms));
        let _ = children[victim].kill();
        eprintln!("recovery-demo: killed process {victim} after {kill_after_ms} ms");
    }
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut statuses: Vec<Option<std::process::ExitStatus>> = vec![None; processes];
    let mut hung = false;
    while statuses.iter().any(Option::is_none) {
        for (i, child) in children.iter_mut().enumerate() {
            if statuses[i].is_none() {
                statuses[i] = child.try_wait().expect("wait for cluster process");
            }
        }
        if Instant::now() >= deadline {
            for (i, child) in children.iter_mut().enumerate() {
                if statuses[i].is_none() {
                    eprintln!("cluster process {i} still running at deadline; killing");
                    let _ = child.kill();
                    statuses[i] = Some(child.wait().expect("wait for killed process"));
                    hung = true;
                }
            }
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    let mut digest = 0u64;
    let mut digests = 0usize;
    let mut failed = hung;
    for (i, mut child) in children.into_iter().enumerate() {
        let mut out = String::new();
        if let Some(mut stdout) = child.stdout.take() {
            let _ = stdout.read_to_string(&mut out);
        }
        print!("{out}");
        let tag = format!("digest[p{i}]: ");
        for line in out.lines() {
            if let Some(rest) = line.strip_prefix(&tag) {
                if let Ok(d) = u64::from_str_radix(rest.trim(), 16) {
                    digest ^= d;
                    digests += 1;
                }
            }
        }
        let status = statuses[i].expect("every child was waited on");
        let expected_kill = kill == Some(i);
        // Exit code 3 is a survivor's orderly "peer lost; quiesced"
        // report — expected exactly when a kill was injected.
        let quiesced = kill.is_some() && status.code() == Some(3);
        if !status.success() && !expected_kill && !quiesced {
            eprintln!("cluster process {i} exited with {status}");
            failed = true;
        }
    }
    if kill.is_none() {
        if digests == processes {
            println!("digest: {digest:016x}");
        } else {
            eprintln!("recovery-demo: only {digests}/{processes} digests reported");
            failed = true;
        }
    }
    std::process::exit(if failed { 1 } else { 0 });
}

/// The `ttd serve` update script for `(key, epoch)`: `None` = no update
/// this epoch, `Some(None)` = delete, `Some(Some(v))` = upsert.
fn serve_update(key: u64, epoch: u64) -> Option<Option<u64>> {
    if (key + epoch) % 5 == 0 {
        return None;
    }
    if (key + epoch) % 7 == 0 {
        return Some(None);
    }
    Some(Some(key * 1_000 + epoch))
}

/// Sequential oracle for the serve script: the value visible for `key`
/// as of `time` after `epochs` fed epochs.
fn serve_oracle(key: u64, time: u64, epochs: u64) -> Option<u64> {
    for epoch in (0..=time.min(epochs - 1)).rev() {
        if let Some(value) = serve_update(key, epoch) {
            return value;
        }
    }
    None
}

/// The `ttd serve` driving client: feeds the script for this process's
/// keys, then verifies every local key at sampled readable times against
/// the oracle — once as fed, once after compacting history below the
/// sampled times — timing each lookup. Returns the mismatch count and
/// the sorted lookup latencies (ns).
fn serve_client(
    plane: std::sync::Arc<ServePlane<u64, u64>>,
    epochs: u64,
    keys: u64,
) -> (u64, Vec<u64>) {
    plane.wait_ready();
    let client = plane.client();
    let local: Vec<u64> = (0..keys).filter(|k| plane.is_local(plane.owner_of(k))).collect();
    for epoch in 0..epochs {
        for &key in &local {
            if let Some(value) = serve_update(key, epoch) {
                client.update(key, value).expect("local key");
            }
        }
        client.advance_to(epoch + 1);
    }
    let times = [epochs / 2, epochs - 1];
    let mut mismatches = 0u64;
    let mut latencies = Vec::new();
    for pass in 0..2 {
        if pass == 1 {
            // Compact below the sampled times: answers must not change.
            client.allow_compaction(epochs / 2);
        }
        for &time in &times {
            for &key in &local {
                let start = Instant::now();
                let got = client.query(key, time).expect("sampled time is readable");
                latencies.push(start.elapsed().as_nanos() as u64);
                if got != serve_oracle(key, time, epochs) {
                    eprintln!(
                        "serve: key {key} at time {time} (pass {pass}): got {got:?}, \
                         oracle says {:?}",
                        serve_oracle(key, time, epochs)
                    );
                    mismatches += 1;
                }
            }
        }
    }
    client.shutdown();
    latencies.sort_unstable();
    (mismatches, latencies)
}

/// Nearest-rank percentile of a sorted ns slice, in microseconds.
fn pctl_us(sorted: &[u64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p / 100.0).round() as usize;
    sorted[idx.min(sorted.len() - 1)] as f64 / 1_000.0
}

fn print_outcome(label: &str, outcome: &Outcome) {
    let lat = latency_cells(outcome);
    match outcome {
        Outcome::Dnf => println!("{label}: DNF (end-to-end latency exceeded 1s)"),
        Outcome::Completed { achieved_rate, histogram, telemetry } => {
            println!(
                "{label}: p50 {} ms  p999 {} ms  max {} ms  ({:.2} M tuples/s, {} stamps)",
                lat[0],
                lat[1],
                lat[2],
                achieved_rate / 1e6,
                histogram.count()
            );
            print_worker_telemetry(telemetry);
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let command = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[argv.len().min(1)..]);

    match command {
        "wordcount" | "noop" => {
            let cluster = args.cluster();
            cluster.validate();
            if cluster.processes > 1 && cluster.process.is_none() {
                orchestrate(cluster.processes);
            }
            let workers = args.get("workers", 4usize);
            let total_workers = workers * cluster.processes;
            let mechanism = args.mechanism();
            let workload = if command == "wordcount" {
                Workload::WordCount
            } else {
                Workload::NoopChain(args.get("chain", 64usize))
            };
            let mut params = Params::new(mechanism, workload);
            params.workers = workers;
            params.rate_per_worker = args.get("rate", 1_000_000u64) / total_workers as u64;
            params.quantum_ns = match workload {
                Workload::WordCount => 1u64 << args.get("quantum-bits", 13u32),
                Workload::NoopChain(_) => {
                    1_000_000_000 / args.get("ticks", 15_000u64).max(1)
                }
            };
            params.duration = Duration::from_millis(args.get("duration-ms", 2000u64));
            params.warmup = Duration::from_millis(args.get("warmup-ms", 500u64));
            let (label, outcome) = match cluster.process {
                Some(process) if cluster.processes > 1 => {
                    println!(
                        "{command}[p{process}]: {mechanism:?}, {} processes x {workers} \
                         workers, quantum {} ns, {:?}",
                        cluster.processes, params.quantum_ns, params.duration
                    );
                    let outcome = run_cluster_observed(
                        params,
                        cluster.processes,
                        process,
                        cluster.addresses,
                        cluster.net,
                        args.observe(),
                    )
                    .unwrap_or_else(|e| {
                        eprintln!("{command}: cluster bootstrap failed: {e}");
                        std::process::exit(1);
                    });
                    (format!("{command}[p{process}]"), outcome)
                }
                _ => {
                    println!(
                        "{command}: {mechanism:?}, {workers} workers, quantum {} ns, {:?}",
                        params.quantum_ns, params.duration
                    );
                    (command.to_string(), run_observed(params, args.observe()))
                }
            };
            print_outcome(&label, &outcome);
        }
        "nexmark" => {
            let cluster = args.cluster();
            cluster.validate();
            if cluster.processes > 1 && cluster.process.is_none() {
                orchestrate(cluster.processes);
            }
            let workers = args.get("workers", 4usize);
            let total_workers = workers * cluster.processes;
            let query = match args.flags.get("query").map(|s| s.as_str()).unwrap_or("q7") {
                "q4" => Query::Q4,
                "q7" => Query::Q7 {
                    window_ns: args.get("window-ms", 100u64) * 1_000_000,
                },
                other => panic!("unknown query {other} (q4|q7)"),
            };
            let mut params = NexmarkParams::new(args.mechanism(), query);
            params.workers = workers;
            params.rate_per_worker = args.get("rate", 500_000u64) / total_workers as u64;
            params.duration = Duration::from_millis(args.get("duration-ms", 2000u64));
            params.warmup = Duration::from_millis(args.get("warmup-ms", 500u64));
            let (label, outcome) = match cluster.process {
                Some(process) if cluster.processes > 1 => {
                    println!(
                        "nexmark {query:?}[p{process}]: {:?}, {} processes x {workers} workers",
                        params.mechanism, cluster.processes
                    );
                    let outcome = run_nexmark_cluster_observed(
                        params,
                        cluster.processes,
                        process,
                        cluster.addresses,
                        cluster.net,
                        args.observe(),
                    )
                    .unwrap_or_else(|e| {
                        eprintln!("nexmark: cluster bootstrap failed: {e}");
                        std::process::exit(1);
                    });
                    (format!("nexmark[p{process}]"), outcome)
                }
                _ => {
                    println!("nexmark {query:?}: {:?}, {workers} workers", params.mechanism);
                    ("nexmark".to_string(), run_nexmark_observed(params, args.observe()))
                }
            };
            print_outcome(&label, &outcome);
        }
        "serve" => {
            let cluster = args.cluster();
            cluster.validate();
            if cluster.processes > 1 && cluster.process.is_none() {
                orchestrate(cluster.processes);
            }
            let workers = args.get("workers", 2usize).max(1);
            let epochs = args.get("epochs", 32u64).max(4);
            let keys = args.get("keys", 64u64).max(1);
            let process_index = cluster.process.unwrap_or(0);
            let peers = workers * cluster.processes;
            // Identity route: key k lives on worker k % peers, so every
            // process owns a verifiable share without hashing.
            let plane =
                ServePlane::<u64, u64>::new(peers, process_index * workers, workers, |k| *k);
            let worker_plane = plane.clone();
            let client = std::thread::spawn(move || serve_client(plane, epochs, keys));
            let config = Config {
                workers,
                pin_workers: false,
                processes: cluster.processes,
                process_index,
                addresses: cluster.addresses,
                net_transport: cluster.net.transport,
                reactor_backend: cluster.net.reactor,
                parking: cluster.net.parking,
                autotune: cluster.net.autotune,
                ..Config::default()
            };
            let stats = if cluster.processes > 1 {
                execute_cluster::<u64, _, _>(config, move |worker| {
                    serve_worker::<u64, u64>(worker, &worker_plane)
                })
                .unwrap_or_else(|e| {
                    eprintln!("serve: cluster bootstrap failed: {e}");
                    std::process::exit(1);
                })
            } else {
                execute::<u64, _, _>(config, move |worker| {
                    serve_worker::<u64, u64>(worker, &worker_plane)
                })
            };
            let (mismatches, latencies) = client.join().expect("serve client thread");
            let answered: u64 = stats.iter().map(|s| s.queries).sum();
            let parked: u64 = stats.iter().map(|s| s.parked).sum();
            let tag = if cluster.processes > 1 {
                format!("serve[p{process_index}]")
            } else {
                "serve".to_string()
            };
            println!(
                "{tag}: {} oracle-verified lookups ({answered} answered, {parked} parked) \
                 over {epochs} epochs x {keys} keys, {workers} workers: \
                 p50 {:.1} us  p99 {:.1} us",
                latencies.len(),
                pctl_us(&latencies, 50.0),
                pctl_us(&latencies, 99.0),
            );
            if mismatches > 0 {
                eprintln!("{tag}: {mismatches} lookups disagreed with the sequential oracle");
                std::process::exit(1);
            }
        }
        "recovery-demo" => {
            let cluster = args.cluster();
            cluster.validate();
            if cluster.processes > 1 && cluster.process.is_none() {
                let kill = args
                    .flags
                    .get("kill-process")
                    .map(|v| v.parse().expect("--kill-process takes a process index"));
                orchestrate_recovery_demo(
                    cluster.processes,
                    kill,
                    args.get("kill-after-ms", 500u64),
                );
            }
            let params = RecoveryDemoParams {
                epochs: args.get("epochs", 200u64),
                words_per_epoch: args.get("words-per-epoch", 64u64),
                vocab: args.get("vocab", 500u64),
                pacing: Duration::from_millis(args.get("epoch-ms", 0u64)),
                crash_after: None,
            };
            // `--recover D` restores from D; `--checkpoint-dir D` +
            // `--checkpoint-interval E` captures into D. A recovered run
            // may also keep capturing by passing both.
            let recover_dir = args.flags.get("recover").cloned();
            let recover = recover_dir.is_some();
            let checkpoint_dir =
                recover_dir.or_else(|| args.flags.get("checkpoint-dir").cloned());
            let process_index = cluster.process.unwrap_or(0);
            let config = Config {
                workers: args.get("workers", 2usize),
                pin_workers: false,
                processes: cluster.processes,
                process_index,
                addresses: cluster.addresses,
                net_transport: cluster.net.transport,
                reactor_backend: cluster.net.reactor,
                parking: cluster.net.parking,
                autotune: cluster.net.autotune,
                checkpoint_dir,
                checkpoint_interval: args.get("checkpoint-interval", 0u64),
                recover,
                trace_path: args.flags.get("trace").cloned(),
                metrics_path: args.flags.get("metrics").cloned(),
                ..Config::default()
            };
            // Both demos share a signature; `--workload` picks the one the
            // chaos/recover cycle exercises (stateful wordcount by default,
            // NEXMark Q4 for token-carrying windowed state).
            let demo: fn(Config, RecoveryDemoParams) -> Result<DemoOutcome, NetError> =
                match args.flags.get("workload").map(String::as_str).unwrap_or("wordcount") {
                    "wordcount" => run_recovery_demo,
                    "q4" => run_q4_recovery_demo,
                    other => panic!("unknown --workload {other} (wordcount|q4)"),
                };
            match demo(config, params) {
                Ok(DemoOutcome::Digest(d)) => {
                    if cluster.processes > 1 {
                        println!("digest[p{process_index}]: {d:016x}");
                    } else {
                        println!("digest: {d:016x}");
                    }
                }
                Ok(DemoOutcome::PeerLost(p)) => {
                    eprintln!(
                        "recovery-demo[p{process_index}]: peer process {p} lost; quiesced \
                         (recover with `ttd recovery-demo --recover <dir>`)"
                    );
                    std::process::exit(3);
                }
                Ok(DemoOutcome::Crashed) => {
                    unreachable!("the CLI injects faults via SIGKILL, not crash_after")
                }
                Err(e) => {
                    eprintln!("recovery-demo: cluster bootstrap failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        "trace-check" => {
            // Structural validation of a `--trace` output file: parses
            // the Chrome JSON, checks span nesting and per-epoch
            // attribution, and (optionally) that every expected worker
            // emitted at least one epoch summary. CI's trace-smoke job
            // gates on this.
            let path = args.flags.get("file").cloned().unwrap_or_else(|| {
                eprintln!("usage: ttd trace-check --file out.json [--expect-workers N]");
                std::process::exit(2);
            });
            let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
                eprintln!("trace-check: cannot read {path}: {e}");
                std::process::exit(1);
            });
            let stats = timestamp_tokens::observe::chrome::validate_trace(&text)
                .unwrap_or_else(|e| {
                    eprintln!("trace-check: {path}: {e}");
                    std::process::exit(1);
                });
            println!(
                "{path}: {} events, {} spans nested, worker tids {:?}, \
                 epoch summaries {:?}",
                stats.events, stats.spans, stats.worker_tids, stats.epoch_summaries
            );
            if stats.attribution_violations > 0 {
                eprintln!(
                    "trace-check: {} epoch summaries attribute more time than their \
                     wall clock",
                    stats.attribution_violations
                );
                std::process::exit(1);
            }
            let expect = args.get("expect-workers", 0usize);
            if expect > 0 {
                if stats.worker_tids.len() != expect {
                    eprintln!(
                        "trace-check: expected {expect} worker threads, saw {:?}",
                        stats.worker_tids
                    );
                    std::process::exit(1);
                }
                for tid in &stats.worker_tids {
                    let epochs = stats
                        .epoch_summaries
                        .iter()
                        .find(|(t, _)| t == tid)
                        .map_or(0, |(_, n)| *n);
                    if epochs == 0 {
                        eprintln!("trace-check: worker tid {tid} emitted no epoch summary");
                        std::process::exit(1);
                    }
                }
            }
            println!("trace-check OK");
        }
        "artifacts" => {
            let dir = args
                .flags
                .get("dir")
                .cloned()
                .unwrap_or_else(|| "artifacts".to_string());
            match timestamp_tokens::runtime::PjrtRuntime::new(&dir) {
                Err(e) => {
                    eprintln!("artifacts check failed: {e:#}");
                    std::process::exit(1);
                }
                Ok(mut runtime) => {
                    for name in runtime.artifact_names() {
                        let meta = runtime.meta(&name).unwrap().clone();
                        match runtime.load(&name) {
                            Ok(_) => println!(
                                "  {name}: OK (n={}, w={}, outputs={})",
                                meta.n, meta.w, meta.outputs
                            ),
                            Err(e) => {
                                eprintln!("  {name}: FAILED: {e:#}");
                                std::process::exit(1);
                            }
                        }
                    }
                    println!("artifacts OK");
                }
            }
        }
        "info" => {
            println!("timestamp-tokens {}", env!("CARGO_PKG_VERSION"));
            println!(
                "cores available: {}",
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
            );
            println!("mechanisms: tokens | notifications | watermarks-x | watermarks-p");
            println!(
                "cluster: --processes N [--process I] [--addresses h:p,...] [--base-port P] \
                 [--net auto|tcp|shm|tcp-threads] [--reactor auto|poll|epoll] \
                 [--parking auto|doorbell|futex] [--autotune on]"
            );
            println!(
                "recovery: --checkpoint-dir D --checkpoint-interval E | --recover D \
                 [--workload wordcount|q4] (see `ttd recovery-demo`)"
            );
            println!(
                "serving: ttd serve [--workers N] [--epochs E] [--keys K] \
                 (oracle-verified frontier-gated lookups; also multi-process)"
            );
            println!(
                "observability: --trace out.json --metrics out.jsonl (any workload; \
                 validate with `ttd trace-check --file out.json`)"
            );
            println!("artifacts dir: artifacts/ (run `make artifacts`)");
        }
        _ => {
            println!(
                "usage: ttd <wordcount|noop|nexmark|serve|recovery-demo|trace-check|artifacts\
                 |info> [--flags]"
            );
            println!("see `ttd info` and the module docs for details");
        }
    }
}
