//! `ttd` — the timestamp-tokens dataflow launcher.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!
//! ```text
//! ttd wordcount  [--workers N] [--rate R] [--quantum-bits B]
//!                [--mechanism tokens|notifications|watermarks-x]
//!                [--duration-ms D]           the §7.2 microbenchmark
//! ttd noop       [--chain N] [--ticks R] ...  the §7.3 idle pipeline
//! ttd nexmark    [--query q4|q7] [--window-ms W] ...   the §7.4 queries
//! ttd artifacts  [--dir PATH]                 verify the PJRT data plane
//! ttd info                                    engine / environment info
//! ```

use std::time::Duration;
use timestamp_tokens::coordination::Mechanism;
use timestamp_tokens::harness::openloop::{run, Outcome, Params, Workload};
use timestamp_tokens::harness::report::{latency_cells, print_worker_telemetry};
use timestamp_tokens::nexmark::bench::{run_nexmark, NexmarkParams, Query};

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(args: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut iter = args.iter();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some(value) = iter.next() {
                    flags.insert(key.to_string(), value.clone());
                }
            }
        }
        Args { flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn mechanism(&self) -> Mechanism {
        self.flags
            .get("mechanism")
            .map(|m| m.parse().expect("tokens|notifications|watermarks-x|watermarks-p"))
            .unwrap_or(Mechanism::Tokens)
    }
}

fn print_outcome(label: &str, outcome: &Outcome) {
    let lat = latency_cells(outcome);
    match outcome {
        Outcome::Dnf => println!("{label}: DNF (end-to-end latency exceeded 1s)"),
        Outcome::Completed { achieved_rate, histogram, telemetry } => {
            println!(
                "{label}: p50 {} ms  p999 {} ms  max {} ms  ({:.2} M tuples/s, {} stamps)",
                lat[0],
                lat[1],
                lat[2],
                achieved_rate / 1e6,
                histogram.count()
            );
            print_worker_telemetry(telemetry);
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let command = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[argv.len().min(1)..]);

    match command {
        "wordcount" | "noop" => {
            let workers = args.get("workers", 4usize);
            let mechanism = args.mechanism();
            let workload = if command == "wordcount" {
                Workload::WordCount
            } else {
                Workload::NoopChain(args.get("chain", 64usize))
            };
            let mut params = Params::new(mechanism, workload);
            params.workers = workers;
            params.rate_per_worker = args.get("rate", 1_000_000u64) / workers as u64;
            params.quantum_ns = match workload {
                Workload::WordCount => 1u64 << args.get("quantum-bits", 13u32),
                Workload::NoopChain(_) => {
                    1_000_000_000 / args.get("ticks", 15_000u64).max(1)
                }
            };
            params.duration = Duration::from_millis(args.get("duration-ms", 2000u64));
            params.warmup = Duration::from_millis(args.get("warmup-ms", 500u64));
            println!(
                "{command}: {mechanism:?}, {workers} workers, quantum {} ns, {:?}",
                params.quantum_ns, params.duration
            );
            let outcome = run(params);
            print_outcome(command, &outcome);
        }
        "nexmark" => {
            let workers = args.get("workers", 4usize);
            let query = match args.flags.get("query").map(|s| s.as_str()).unwrap_or("q7") {
                "q4" => Query::Q4,
                "q7" => Query::Q7 {
                    window_ns: args.get("window-ms", 100u64) * 1_000_000,
                },
                other => panic!("unknown query {other} (q4|q7)"),
            };
            let mut params = NexmarkParams::new(args.mechanism(), query);
            params.workers = workers;
            params.rate_per_worker = args.get("rate", 500_000u64) / workers as u64;
            params.duration = Duration::from_millis(args.get("duration-ms", 2000u64));
            params.warmup = Duration::from_millis(args.get("warmup-ms", 500u64));
            println!("nexmark {query:?}: {:?}, {workers} workers", params.mechanism);
            let outcome = run_nexmark(params);
            print_outcome("nexmark", &outcome);
        }
        "artifacts" => {
            let dir = args
                .flags
                .get("dir")
                .cloned()
                .unwrap_or_else(|| "artifacts".to_string());
            match timestamp_tokens::runtime::PjrtRuntime::new(&dir) {
                Err(e) => {
                    eprintln!("artifacts check failed: {e:#}");
                    std::process::exit(1);
                }
                Ok(mut runtime) => {
                    for name in runtime.artifact_names() {
                        let meta = runtime.meta(&name).unwrap().clone();
                        match runtime.load(&name) {
                            Ok(_) => println!(
                                "  {name}: OK (n={}, w={}, outputs={})",
                                meta.n, meta.w, meta.outputs
                            ),
                            Err(e) => {
                                eprintln!("  {name}: FAILED: {e:#}");
                                std::process::exit(1);
                            }
                        }
                    }
                    println!("artifacts OK");
                }
            }
        }
        "info" => {
            println!("timestamp-tokens {}", env!("CARGO_PKG_VERSION"));
            println!(
                "cores available: {}",
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
            );
            println!("mechanisms: tokens | notifications | watermarks-x | watermarks-p");
            println!("artifacts dir: artifacts/ (run `make artifacts`)");
        }
        _ => {
            println!("usage: ttd <wordcount|noop|nexmark|artifacts|info> [--flags]");
            println!("see `ttd info` and the module docs for details");
        }
    }
}
