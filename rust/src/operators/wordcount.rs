//! The paper's microbenchmark operator (§7.2): "a single stateful operator
//! that computes the overall rolling count of unique words observed on the
//! inputs. Every time the operator receives a word, it updates the internal
//! count, and sends an output message with the updated value."
//!
//! This is the timestamp-token implementation: the operator is *oblivious*
//! — it emits with each input batch's token reference and never retains a
//! token, so the only coordination traffic is message accounting, whatever
//! the timestamp granularity. (The Naiad-notification and Flink-watermark
//! variants used for comparison live in `crate::coordination`.)

use crate::dataflow::channels::{Data, Pact};
use crate::dataflow::operator::OperatorExt;
use crate::dataflow::stream::Stream;
use crate::progress::timestamp::Timestamp;
use crate::recovery::{epoch_of, EpochSealed};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Rolling word counts.
pub trait WordCountExt<T: Timestamp> {
    /// Exchanges words by value and maintains a rolling count per word,
    /// emitting `(word, new_count)` for every record.
    fn word_count(&self) -> Stream<T, (u64, u64)>;
}

impl<T: Timestamp> WordCountExt<T> for Stream<T, u64> {
    fn word_count(&self) -> Stream<T, (u64, u64)> {
        let recovery = self.scope().recovery();
        let peers = self.scope().peers() as u64;
        let index = self.scope().index() as u64;
        self.unary(Pact::exchange(|w: &u64| *w), "word_count", move |tok, _info| {
            drop(tok);
            // Counts live in an epoch-sealed cell so frontier-aligned
            // checkpoints can capture them; the apply function returns the
            // new count, keeping the hot path at one hash lookup.
            fn bump(counts: &mut HashMap<u64, u64>, word: &u64) -> u64 {
                let count = counts.entry(*word).or_insert(0);
                *count += 1;
                *count
            }
            let logging = recovery.as_ref().is_some_and(|r| r.logging());
            let cell = Rc::new(RefCell::new(EpochSealed::new(HashMap::new(), bump, logging)));
            if let Some(ctx) = &recovery {
                // Words route by value (`w % peers`), so a restoring
                // worker keeps exactly the words the *new* shape assigns
                // to it — this is what lets a checkpoint restore into a
                // different cluster shape.
                ctx.register("word_count", cell.clone(), move |into, _old_worker, old| {
                    into.extend(old.into_iter().filter(|(w, _)| w % peers == index));
                });
            }
            move |input: &mut _, output: &mut _| {
                let mut cell = cell.borrow_mut();
                while let Some((token, data)) = input.next() {
                    let epoch = epoch_of(token.time());
                    let mut session = output.session(&token);
                    for word in data {
                        let count = cell.update(epoch, word);
                        session.give((word, count));
                    }
                }
            }
        })
    }
}

/// A generic hash usable as an exchange key for string-ish data.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut hash = 0xcbf29ce484222325u64;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x100000001b3);
    }
    hash
}

/// Rolling counts over arbitrary hashable data, for the quickstart example.
pub trait GeneralWordCountExt<T: Timestamp, D: Data + std::hash::Hash + Eq> {
    /// Exchanges records by hash and emits `(record, new_count)` per record.
    fn rolling_count(&self) -> Stream<T, (D, u64)>;
}

impl<T: Timestamp, D: Data + std::hash::Hash + Eq> GeneralWordCountExt<T, D> for Stream<T, D> {
    fn rolling_count(&self) -> Stream<T, (D, u64)> {
        use std::hash::{Hash, Hasher};
        fn hash_of<D: Hash>(d: &D) -> u64 {
            let mut h = std::collections::hash_map::DefaultHasher::new();
            d.hash(&mut h);
            h.finish()
        }
        self.unary(Pact::exchange(hash_of::<D>), "rolling_count", |tok, _info| {
            drop(tok);
            let mut counts: HashMap<D, u64> = HashMap::new();
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    let mut session = output.session(&token);
                    for record in data {
                        let count = counts.entry(record.clone()).or_insert(0);
                        *count += 1;
                        session.give((record, *count));
                    }
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::probe::ProbeExt;
    use crate::worker::execute::{execute, execute_single};

    #[test]
    fn counts_accumulate() {
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let out = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
            let out2 = out.clone();
            let probe = stream
                .word_count()
                .probe_with(move |_t, data| out2.borrow_mut().extend_from_slice(data));
            for w in [7u64, 7, 9, 7] {
                input.send(w);
            }
            input.close();
            worker.step_while(|| !probe.done());
            let got = out.borrow().clone(); got
        });
        let mut got = got;
        got.sort();
        assert_eq!(got, vec![(7, 1), (7, 2), (7, 3), (9, 1)]);
    }

    #[test]
    fn counts_exchange_across_workers() {
        // Each worker feeds the same two words; counts must aggregate
        // globally (each word owned by one worker).
        let results = execute::<u64, _, _>(
            crate::config::Config { workers: 2, pin_workers: false, ..Default::default() },
            |worker| {
                let (mut input, stream) = worker.new_input::<u64>();
                let out = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
                let out2 = out.clone();
                let probe = stream
                    .word_count()
                    .probe_with(move |_t, data| out2.borrow_mut().extend_from_slice(data));
                input.send(4); // routed to worker 0
                input.send(5); // routed to worker 1
                input.close();
                worker.step_while(|| !probe.done());
                let got = out.borrow().clone(); got
            },
        );
        let mut all: Vec<_> = results.into_iter().flatten().collect();
        all.sort();
        // Two workers sent each word once; final counts reach 2.
        assert_eq!(all, vec![(4, 1), (4, 2), (5, 1), (5, 2)]);
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a(b"abc"), fnv1a(b"abc"));
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
    }
}
