//! Stock operators, written against the public token API — exactly the code
//! a "system implementor" writes once so end users can invoke it (§5).

pub mod map;
pub mod noop;
pub mod window;
pub mod wordcount;

/// Convenience re-exports.
pub mod prelude {
    pub use super::map::MapExt;
    pub use super::noop::NoopExt;
    pub use super::window::{WindowAverageExt, WindowBackend};
    pub use super::wordcount::{GeneralWordCountExt, WordCountExt};
}
