//! Streaming record-at-a-time operators: map, filter, inspect, exchange,
//! concat.
//!
//! These are the paper's "oblivious" operators (§3.2): they "can be
//! oblivious to [frontier] information and process data as it arrives",
//! sending output with the timestamp token reference that accompanies each
//! input batch — no retained tokens, no system interaction beyond message
//! accounting.
//!
//! On pipeline channels these operators are also *copy-free*: a uniquely
//! owned input batch is transformed **in place** where the logic permits
//! ([`MapExt::map_in_place`] mutates records in the arriving buffer,
//! [`MapExt::filter`] retains in place) and the same buffer is then handed
//! to the next operator whole via [`Session::give_batch`]'s lease
//! forwarding — one heap buffer rides the entire pipeline.
//!
//! [`Session::give_batch`]: crate::dataflow::operator::Session::give_batch

use crate::dataflow::channels::{Batch, Data, Pact};
use crate::dataflow::operator::{OperatorBuilder, OperatorExt};
use crate::dataflow::stream::Stream;
use crate::dataflow::InputHandle;
use crate::progress::location::Location;
use crate::progress::timestamp::Timestamp;

/// Record-at-a-time transforms.
pub trait MapExt<T: Timestamp, D: Data> {
    /// Applies `logic` to each record.
    fn map<D2: Data, F: FnMut(D) -> D2 + 'static>(&self, logic: F) -> Stream<T, D2>;

    /// Applies `logic` to each record *in place*, preserving the record
    /// type. Uniquely owned batches are mutated in their arriving buffer
    /// and forwarded whole (no per-record move, no re-buffering) — the
    /// copy-free complement of [`map`](MapExt::map) for pipeline chains.
    fn map_in_place<F: FnMut(&mut D) + 'static>(&self, logic: F) -> Stream<T, D>;

    /// Keeps records satisfying `predicate`. Uniquely owned batches are
    /// filtered in place (`Vec::retain`) and forwarded whole.
    fn filter<F: FnMut(&D) -> bool + 'static>(&self, predicate: F) -> Stream<T, D>;

    /// Passes records through, applying `logic` to each (for debugging).
    fn inspect<F: FnMut(&T, &D) + 'static>(&self, logic: F) -> Stream<T, D>;

    /// Re-routes records between workers by `key`.
    fn exchange<F: Fn(&D) -> u64 + 'static>(&self, key: F) -> Stream<T, D>;

    /// Merges this stream with `other` (both pipeline pacts).
    fn concat(&self, other: &Stream<T, D>) -> Stream<T, D>;
}

impl<T: Timestamp, D: Data> MapExt<T, D> for Stream<T, D> {
    fn map<D2: Data, F: FnMut(D) -> D2 + 'static>(&self, mut logic: F) -> Stream<T, D2> {
        self.unary(Pact::Pipeline, "map", move |tok, _info| {
            drop(tok); // oblivious operator: no unprompted output
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    output.session(&token).give_iterator(data.into_iter().map(&mut logic));
                }
            }
        })
    }

    fn map_in_place<F: FnMut(&mut D) + 'static>(&self, mut logic: F) -> Stream<T, D> {
        self.unary(Pact::Pipeline, "map_in_place", move |tok, _info| {
            drop(tok);
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    match data {
                        Batch::Owned(mut lease) => {
                            // Unique buffer: mutate in place, forward whole.
                            for record in lease.iter_mut() {
                                logic(record);
                            }
                            output.session(&token).give_batch(Batch::Owned(lease));
                        }
                        shared => {
                            output.session(&token).give_iterator(shared.into_iter().map(
                                |mut record| {
                                    logic(&mut record);
                                    record
                                },
                            ));
                        }
                    }
                }
            }
        })
    }

    fn filter<F: FnMut(&D) -> bool + 'static>(&self, mut predicate: F) -> Stream<T, D> {
        self.unary(Pact::Pipeline, "filter", move |tok, _info| {
            drop(tok);
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    match data {
                        Batch::Owned(mut lease) => {
                            // Unique buffer: retain in place, forward whole
                            // (an empty result posts nothing and recycles
                            // the buffer).
                            lease.retain(|d| predicate(d));
                            output.session(&token).give_batch(Batch::Owned(lease));
                        }
                        shared => {
                            output
                                .session(&token)
                                .give_iterator(shared.into_iter().filter(|d| predicate(d)));
                        }
                    }
                }
            }
        })
    }

    fn inspect<F: FnMut(&T, &D) + 'static>(&self, mut logic: F) -> Stream<T, D> {
        self.unary(Pact::Pipeline, "inspect", move |tok, _info| {
            drop(tok);
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    let time = token.time().clone();
                    for d in &data {
                        logic(&time, d);
                    }
                    output.session(&token).give_batch(data);
                }
            }
        })
    }

    fn exchange<F: Fn(&D) -> u64 + 'static>(&self, key: F) -> Stream<T, D> {
        self.unary(Pact::exchange(key), "exchange", move |tok, _info| {
            drop(tok);
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    output.session(&token).give_batch(data);
                }
            }
        })
    }

    fn concat(&self, other: &Stream<T, D>) -> Stream<T, D> {
        // Both streams feed the SAME input port: one queue, one frontier
        // (the tracker merges the two edges' constraints automatically).
        let scope = self.scope();
        let mut builder = OperatorBuilder::new(&scope, "concat");
        let (queue, frontier, port) = builder.new_input(self, Pact::Pipeline);
        other.connect_to(builder.node(), port, Pact::Pipeline, queue.clone());
        let (tee, stream) = builder.new_output::<D>();
        let (info, activation) = builder.info();
        let node = builder.node();
        let bookkeeping = scope.bookkeeping();
        drop(builder.initial_tokens());
        let mut input: InputHandle<T, D> = InputHandle::new(
            queue,
            frontier,
            Location::target(node, 0),
            Some(Location::source(node, 0)),
            T::Summary::default(),
            bookkeeping.clone(),
        );
        let mut output = crate::dataflow::OutputHandle::new(
            Location::source(node, 0),
            tee,
            bookkeeping,
            info.worker,
            info.peers,
            scope.send_batch(),
        );
        let tracer = scope.tracer();
        input.set_tracer(tracer.clone());
        output.set_tracer(tracer);
        builder.build(
            activation,
            Box::new(move || {
                while let Some((token, data)) = input.next() {
                    output.session(&token).give_batch(data);
                }
            }),
        );
        stream
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::probe::ProbeExt;
    use crate::worker::execute::execute_single;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn map_filter_roundtrip() {
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = seen.clone();
            let probe = stream
                .map(|x| x * 2)
                .filter(|x| x % 4 == 0)
                .inspect(move |t, x| seen2.borrow_mut().push((*t, *x)))
                .probe();
            for t in 0..4u64 {
                input.advance_to(t);
                input.send(t);
            }
            input.close();
            worker.step_while(|| !probe.done());
            let got = seen.borrow().clone(); got
        });
        // x*2 for x in 0..4 = [0,2,4,6]; keep multiples of 4: 0 (t=0), 4 (t=2).
        assert_eq!(got, vec![(0, 0), (2, 4)]);
    }

    #[test]
    fn map_in_place_transforms_and_preserves_order() {
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = seen.clone();
            let probe = stream
                .map_in_place(|x| *x *= 10)
                .map_in_place(|x| *x += 1)
                .inspect(move |_t, x| seen2.borrow_mut().push(*x))
                .probe();
            for x in 0..5u64 {
                input.send(x);
            }
            input.close();
            worker.step_while(|| !probe.done());
            let got = seen.borrow().clone();
            got
        });
        assert_eq!(got, vec![1, 11, 21, 31, 41]);
    }

    /// A uniquely owned batch on a single pipeline channel is forwarded
    /// WHOLE: the same heap buffer (observed by pointer) travels from the
    /// first operator through the chain to the final consumer.
    #[test]
    fn pipeline_forwarding_hands_off_the_same_buffer() {
        let ptrs = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let ptrs = Rc::new(RefCell::new(Vec::new()));
            let (p1, p2) = (ptrs.clone(), ptrs.clone());
            let forwarded = stream.unary::<u64, _, _>(Pact::Pipeline, "head", move |tok, _info| {
                drop(tok);
                move |input: &mut _, output: &mut crate::dataflow::OutputHandle<u64, u64>| {
                    while let Some((token, data)) = input.next() {
                        p1.borrow_mut().push(data.as_slice().as_ptr() as usize);
                        output.session(&token).give_batch(data);
                    }
                }
            });
            forwarded.sink(Pact::Pipeline, "tail", move |_info| {
                move |input: &mut crate::dataflow::InputHandle<u64, u64>| {
                    while let Some((_token, data)) = input.next() {
                        p2.borrow_mut().push(data.as_slice().as_ptr() as usize);
                    }
                }
            });
            for x in 0..100u64 {
                input.send(x);
            }
            input.close();
            worker.step_while(|| {
                let state = ptrs.borrow();
                state.len() < 2
            });
            let got = ptrs.borrow().clone();
            got
        });
        assert_eq!(ptrs.len(), 2, "one batch seen at the head and at the tail");
        assert_eq!(ptrs[0], ptrs[1], "forwarding must hand off the same buffer");
    }

    /// Records given individually before a forwarded batch in the same
    /// session are delivered first (the forwarding order barrier).
    #[test]
    fn forwarding_preserves_session_order() {
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = seen.clone();
            let probe = stream
                .unary::<u64, _, _>(Pact::Pipeline, "prefix", move |tok, _info| {
                    drop(tok);
                    move |input: &mut _, output: &mut crate::dataflow::OutputHandle<u64, u64>| {
                        while let Some((token, data)) = input.next() {
                            let mut session = output.session(&token);
                            session.give(999);
                            session.give_batch(data);
                        }
                    }
                })
                .inspect(move |_t, x| seen2.borrow_mut().push(*x))
                .probe();
            for x in 1..4u64 {
                input.send(x);
            }
            input.close();
            worker.step_while(|| !probe.done());
            let got = seen.borrow().clone();
            got
        });
        assert_eq!(got, vec![999, 1, 2, 3], "given records must precede the forwarded batch");
    }

    /// With two downstream consumers forwarding is declined (the batch
    /// must be duplicated) and every consumer still sees every record.
    #[test]
    fn forwarding_declined_with_two_consumers() {
        let (a, b) = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let passed = stream.map_in_place(|x| *x += 100);
            let seen_a = Rc::new(RefCell::new(Vec::new()));
            let seen_b = Rc::new(RefCell::new(Vec::new()));
            let (sa, sb) = (seen_a.clone(), seen_b.clone());
            let pa = passed.inspect(move |_t, x| sa.borrow_mut().push(*x)).probe();
            let pb = passed.inspect(move |_t, x| sb.borrow_mut().push(*x)).probe();
            for x in 0..3u64 {
                input.send(x);
            }
            input.close();
            worker.step_while(|| !pa.done() || !pb.done());
            let a = seen_a.borrow().clone();
            let b = seen_b.borrow().clone();
            (a, b)
        });
        assert_eq!(a, vec![100, 101, 102]);
        assert_eq!(b, vec![100, 101, 102]);
    }

    #[test]
    fn filter_in_place_keeps_matching_records() {
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = seen.clone();
            let probe = stream
                .filter(|x| x % 3 == 0)
                .inspect(move |_t, x| seen2.borrow_mut().push(*x))
                .probe();
            for x in 0..10u64 {
                input.send(x);
            }
            input.close();
            worker.step_while(|| !probe.done());
            let got = seen.borrow().clone();
            got
        });
        assert_eq!(got, vec![0, 3, 6, 9]);
    }

    #[test]
    fn concat_merges_streams() {
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut in1, s1) = worker.new_input::<u64>();
            let (mut in2, s2) = worker.new_input::<u64>();
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = seen.clone();
            let probe = s1
                .concat(&s2)
                .inspect(move |_t, x| seen2.borrow_mut().push(*x))
                .probe();
            in1.send(1);
            in2.send(2);
            in1.close();
            in2.close();
            worker.step_while(|| !probe.done());
            let mut v = seen.borrow().clone();
            v.sort();
            v
        });
        assert_eq!(got, vec![1, 2]);
    }
}
