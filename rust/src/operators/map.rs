//! Streaming record-at-a-time operators: map, filter, inspect, exchange,
//! concat.
//!
//! These are the paper's "oblivious" operators (§3.2): they "can be
//! oblivious to [frontier] information and process data as it arrives",
//! sending output with the timestamp token reference that accompanies each
//! input batch — no retained tokens, no system interaction beyond message
//! accounting.

use crate::dataflow::channels::{Data, Pact};
use crate::dataflow::operator::{OperatorBuilder, OperatorExt};
use crate::dataflow::stream::Stream;
use crate::dataflow::InputHandle;
use crate::progress::location::Location;
use crate::progress::timestamp::Timestamp;

/// Record-at-a-time transforms.
pub trait MapExt<T: Timestamp, D: Data> {
    /// Applies `logic` to each record.
    fn map<D2: Data, F: FnMut(D) -> D2 + 'static>(&self, logic: F) -> Stream<T, D2>;

    /// Keeps records satisfying `predicate`.
    fn filter<F: FnMut(&D) -> bool + 'static>(&self, predicate: F) -> Stream<T, D>;

    /// Passes records through, applying `logic` to each (for debugging).
    fn inspect<F: FnMut(&T, &D) + 'static>(&self, logic: F) -> Stream<T, D>;

    /// Re-routes records between workers by `key`.
    fn exchange<F: Fn(&D) -> u64 + 'static>(&self, key: F) -> Stream<T, D>;

    /// Merges this stream with `other` (both pipeline pacts).
    fn concat(&self, other: &Stream<T, D>) -> Stream<T, D>;
}

impl<T: Timestamp, D: Data> MapExt<T, D> for Stream<T, D> {
    fn map<D2: Data, F: FnMut(D) -> D2 + 'static>(&self, mut logic: F) -> Stream<T, D2> {
        self.unary(Pact::Pipeline, "map", move |tok, _info| {
            drop(tok); // oblivious operator: no unprompted output
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    output.session(&token).give_iterator(data.into_iter().map(&mut logic));
                }
            }
        })
    }

    fn filter<F: FnMut(&D) -> bool + 'static>(&self, mut predicate: F) -> Stream<T, D> {
        self.unary(Pact::Pipeline, "filter", move |tok, _info| {
            drop(tok);
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    output
                        .session(&token)
                        .give_iterator(data.into_iter().filter(|d| predicate(d)));
                }
            }
        })
    }

    fn inspect<F: FnMut(&T, &D) + 'static>(&self, mut logic: F) -> Stream<T, D> {
        self.unary(Pact::Pipeline, "inspect", move |tok, _info| {
            drop(tok);
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    let time = token.time().clone();
                    for d in &data {
                        logic(&time, d);
                    }
                    output.session(&token).give_batch(data);
                }
            }
        })
    }

    fn exchange<F: Fn(&D) -> u64 + 'static>(&self, key: F) -> Stream<T, D> {
        self.unary(Pact::exchange(key), "exchange", move |tok, _info| {
            drop(tok);
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    output.session(&token).give_batch(data);
                }
            }
        })
    }

    fn concat(&self, other: &Stream<T, D>) -> Stream<T, D> {
        // Both streams feed the SAME input port: one queue, one frontier
        // (the tracker merges the two edges' constraints automatically).
        let scope = self.scope();
        let mut builder = OperatorBuilder::new(&scope, "concat");
        let (queue, frontier, port) = builder.new_input(self, Pact::Pipeline);
        other.connect_to(builder.node(), port, Pact::Pipeline, queue.clone());
        let (tee, stream) = builder.new_output::<D>();
        let (info, activation) = builder.info();
        let node = builder.node();
        let bookkeeping = scope.bookkeeping();
        drop(builder.initial_tokens());
        let mut input: InputHandle<T, D> = InputHandle::new(
            queue,
            frontier,
            Location::target(node, 0),
            Some(Location::source(node, 0)),
            T::Summary::default(),
            bookkeeping.clone(),
        );
        let mut output = crate::dataflow::OutputHandle::new(
            Location::source(node, 0),
            tee,
            bookkeeping,
            info.worker,
            info.peers,
            scope.send_batch(),
        );
        builder.build(
            activation,
            Box::new(move || {
                while let Some((token, data)) = input.next() {
                    output.session(&token).give_batch(data);
                }
            }),
        );
        stream
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::probe::ProbeExt;
    use crate::worker::execute::execute_single;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn map_filter_roundtrip() {
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = seen.clone();
            let probe = stream
                .map(|x| x * 2)
                .filter(|x| x % 4 == 0)
                .inspect(move |t, x| seen2.borrow_mut().push((*t, *x)))
                .probe();
            for t in 0..4u64 {
                input.advance_to(t);
                input.send(t);
            }
            input.close();
            worker.step_while(|| !probe.done());
            let got = seen.borrow().clone(); got
        });
        // x*2 for x in 0..4 = [0,2,4,6]; keep multiples of 4: 0 (t=0), 4 (t=2).
        assert_eq!(got, vec![(0, 0), (2, 4)]);
    }

    #[test]
    fn concat_merges_streams() {
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut in1, s1) = worker.new_input::<u64>();
            let (mut in2, s2) = worker.new_input::<u64>();
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = seen.clone();
            let probe = s1
                .concat(&s2)
                .inspect(move |_t, x| seen2.borrow_mut().push(*x))
                .probe();
            in1.send(1);
            in2.send(2);
            in1.close();
            in2.close();
            worker.step_while(|| !probe.done());
            let mut v = seen.borrow().clone();
            v.sort();
            v
        });
        assert_eq!(got, vec![1, 2]);
    }
}
