//! The tumbling windowed average of the paper's §5 / Figure 5.
//!
//! The operator receives timestamped integer-valued messages and reports
//! the average every `WINDOW_SIZE` timestamp units, at the timestamp of the
//! start of the next window, producing no output for empty windows. The
//! implementation below mirrors the paper's listing: an ordered map from
//! end-of-window timestamp to `(TimestampToken, WindowData)`, tokens
//! captured from input with `retain` and immediately downgraded to the
//! window end, and window retirement driven by `input.frontier()`.
//!
//! The per-batch accumulation step is pluggable ([`WindowBackend`]): the
//! native backend folds in Rust; the XLA backend
//! (`runtime::XlaWindowBackend`) runs the AOT-compiled JAX/Pallas
//! segmented-aggregation kernel via PJRT.

use crate::dataflow::channels::Pact;
use crate::dataflow::operator::OperatorExt;
use crate::dataflow::stream::Stream;
use crate::net::{Wire, WireError, WireReader};
use crate::progress::antichain::MutableAntichain;
use crate::recovery::EpochSealed;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

/// User-defined structure to maintain window data (Ⓐ in Figure 5).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WindowData {
    /// Sum of values observed in the window.
    pub sum: u64,
    /// Number of values observed in the window.
    pub count: u64,
}

impl Wire for WindowData {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.sum.encode(buf);
        self.count.encode(buf);
    }
    fn decode(reader: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(WindowData { sum: u64::decode(reader)?, count: u64::decode(reader)? })
    }
}

/// One epoch-tagged mutation of the open-window map, routed through the
/// [`EpochSealed`] cell so checkpoints capture exactly the windows still
/// open at the sealed epoch. `Close` is tagged with the window end itself:
/// the operator holds that window's token until it closes it, so the
/// frontier — and therefore the seal — cannot pass the window end first,
/// and a seal that applies the `Close` has already applied every `Add`.
enum WindowUpdate {
    /// Fold a batch partial into the window ending at `window`.
    Add { window: u64, sum: u64, count: u64 },
    /// Retire the window ending at `window` (output already emitted).
    Close { window: u64 },
}

fn apply_window(state: &mut BTreeMap<u64, WindowData>, update: &WindowUpdate) {
    match update {
        WindowUpdate::Add { window, sum, count } => {
            let entry = state.entry(*window).or_default();
            entry.sum += sum;
            entry.count += count;
        }
        WindowUpdate::Close { window } => {
            state.remove(window);
        }
    }
}

/// The paper's `singleton_frontier` helper: the sole element of a totally
/// ordered frontier, or `u64::MAX` when the frontier is closed.
pub fn singleton_frontier(frontier: &MutableAntichain<u64>) -> u64 {
    frontier.frontier().first().cloned().unwrap_or(u64::MAX)
}

/// Pluggable batch-accumulation backend for windowing operators.
///
/// Given a batch of `(window_end, value)` pairs, returns per-window partial
/// aggregates `(window_end, sum, count)`.
pub trait WindowBackend: 'static {
    /// Aggregates one input batch into per-window partials.
    fn aggregate(&mut self, items: &[(u64, u64)]) -> Vec<(u64, u64, u64)>;
    /// Backend name (diagnostics).
    fn name(&self) -> &'static str;
}

/// Plain Rust accumulation.
pub struct NativeWindowBackend;

impl WindowBackend for NativeWindowBackend {
    fn aggregate(&mut self, items: &[(u64, u64)]) -> Vec<(u64, u64, u64)> {
        let mut partials: BTreeMap<u64, (u64, u64)> = BTreeMap::new();
        for &(window, value) in items {
            let entry = partials.entry(window).or_insert((0, 0));
            entry.0 += value;
            entry.1 += 1;
        }
        partials.into_iter().map(|(w, (s, c))| (w, s, c)).collect()
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Rounds `ts` up to the next multiple of `window_size` (the Ⓙ helper:
/// the end-of-window timestamp of the window containing `ts`).
/// Saturates at `u64::MAX` for timestamps near the top of the domain,
/// so late-domain data lands in a final partial window instead of
/// overflowing.
pub fn round_up_to_multiple(ts: u64, window_size: u64) -> u64 {
    (ts / window_size).saturating_add(1).saturating_mul(window_size)
}

/// Tumbling windowed averages.
pub trait WindowAverageExt {
    /// The paper's Figure 5 operator: averages per `window_size` tumbling
    /// window, emitted at the end-of-window timestamp; empty windows
    /// produce nothing.
    fn window_average(
        &self,
        window_size: u64,
        backend: Box<dyn WindowBackend>,
    ) -> Stream<u64, f64>;
}

impl WindowAverageExt for Stream<u64, u64> {
    fn window_average(
        &self,
        window_size: u64,
        mut backend: Box<dyn WindowBackend>,
    ) -> Stream<u64, f64> {
        let peers = self.scope().peers() as u64;
        let recovery = self.scope().recovery();
        let my_index = self.scope().index();
        // Figure 5 Ⓑ: the outer function, invoked once with the initial
        // timestamp token Ⓒ.
        self.unary_frontier(
            Pact::exchange(move |x: &u64| *x % peers),
            "tumbling_window",
            move |tok, _info| {
                // Ⓓ, Ⓔ: the initial token is at time zero — normally
                // dropped immediately (this operator produces no
                // unprompted output); on restore it first re-mints one
                // token per restored open window.
                assert!(*tok.time() == 0);
                // Ⓕ: ordered map from end-of-window timestamp to the held
                // token; the partial window data lives in the epoch-sealed
                // cell (only the data is checkpointed — tokens are
                // re-minted on restore).
                let mut tokens: BTreeMap<u64, crate::dataflow::TimestampToken<u64>> =
                    BTreeMap::new();
                let logging = recovery.as_ref().is_some_and(|r| r.logging());
                let cell = Rc::new(RefCell::new(EpochSealed::new(
                    BTreeMap::<u64, WindowData>::new(),
                    apply_window,
                    logging,
                )));
                if let Some(ctx) = &recovery {
                    // This stage exchanges by VALUE (`x % peers`), not by
                    // window, so every worker holds partials for the same
                    // windows: each restoring worker takes only its own
                    // old worker's chunk (no rescaling for this operator).
                    let restored =
                        ctx.register("tumbling_window", cell.clone(), move |into, old_worker, old| {
                            if old_worker == my_index {
                                into.extend(old);
                            }
                        });
                    if restored {
                        // Re-mint one token per restored open window from
                        // the initial token, which is still at time zero.
                        for &w in cell.borrow().state().keys() {
                            tokens.insert(w, tok.delayed(&w));
                        }
                    }
                }
                std::mem::drop(tok);
                let mut batch_scratch: Vec<(u64, u64)> = Vec::new();
                // Ⓖ: the operator logic, invoked per scheduling.
                move |input: &mut _, output: &mut _| {
                    let mut cell = cell.borrow_mut();
                    // Ⓘ: per-batch input processing.
                    while let Some((tok_ref, data)) = input.next() {
                        // Ⓙ: the window this batch belongs to.
                        let window_ts = round_up_to_multiple(*tok_ref.time(), window_size);
                        let epoch = crate::recovery::epoch_of(tok_ref.time());
                        // Ⓚ, Ⓛ: first data for this window — capture the
                        // token and downgrade it to the window end.
                        if !tokens.contains_key(&window_ts) {
                            let mut window_tok = tok_ref.retain();
                            window_tok.downgrade(&window_ts);
                            tokens.insert(window_ts, window_tok);
                        }
                        // Ⓜ: fold the batch into the window partials via
                        // the configured backend.
                        batch_scratch.clear();
                        batch_scratch.extend(data.iter().map(|&v| (window_ts, v)));
                        for (w, sum, count) in backend.aggregate(&batch_scratch) {
                            cell.update(epoch, WindowUpdate::Add { window: w, sum, count });
                        }
                    }
                    // Ⓝ: the frontier tells us which windows can close. An
                    // *empty* frontier (end of stream) closes everything —
                    // including a final partial window saturated at exactly
                    // `u64::MAX`, which the exclusive `..target_ts` range
                    // below can never reach. Without the inclusive case
                    // that window's token is held forever and the dataflow
                    // never drains.
                    let frontier_empty = input.frontier().frontier().first().is_none();
                    let target_ts = singleton_frontier(&input.frontier());
                    let closed = |w: &u64| *w < target_ts || frontier_empty;
                    // Ⓟ, Ⓠ, Ⓡ: retire all closed windows at once, using
                    // the stored tokens.
                    for (w, tok) in tokens.iter().filter(|(w, _)| closed(w)) {
                        let window =
                            cell.state().get(w).copied().unwrap_or_default();
                        output
                            .session(tok)
                            .give(window.sum as f64 / window.count as f64);
                    }
                    // Ⓢ: drop retired windows; token drops update the
                    // system automatically (and eagerly). The `Close` is
                    // tagged with the window end (see [`WindowUpdate`]).
                    let retired: Vec<u64> =
                        tokens.keys().filter(|&w| closed(w)).copied().collect();
                    for k in retired {
                        tokens.remove(&k);
                        cell.update(k, WindowUpdate::Close { window: k });
                    }
                }
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::probe::ProbeExt;
    use crate::worker::execute::execute_single;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn run_window(values: Vec<(u64, u64)>, window: u64) -> Vec<(u64, f64)> {
        execute_single::<u64, _, _>(move |worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let out = Rc::new(RefCell::new(Vec::new()));
            let out2 = out.clone();
            let probe = stream
                .window_average(window, Box::new(NativeWindowBackend))
                .probe_with(move |t, data| {
                    for d in data {
                        out2.borrow_mut().push((*t, *d));
                    }
                });
            for (t, v) in values.clone() {
                input.advance_to(t);
                input.send(v);
            }
            input.close();
            worker.step_while(|| !probe.done());
            let mut v = out.borrow().clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v
        })
    }

    #[test]
    fn averages_per_window() {
        // Window [0,10): values 2, 4 -> avg 3 at ts 10.
        // Window [10,20): value 10  -> avg 10 at ts 20.
        let got = run_window(vec![(1, 2), (3, 4), (12, 10)], 10);
        assert_eq!(got, vec![(10, 3.0), (20, 10.0)]);
    }

    #[test]
    fn empty_windows_produce_nothing() {
        // Data only in [0,10) and [30,40): two outputs, none for the gap.
        let got = run_window(vec![(5, 6), (35, 8)], 10);
        assert_eq!(got, vec![(10, 6.0), (40, 8.0)]);
    }

    #[test]
    fn burst_retires_multiple_windows_at_once() {
        // All data arrives before the input advances: when the frontier
        // jumps to 100, three windows retire in one invocation.
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let out = Rc::new(RefCell::new(Vec::new()));
            let out2 = out.clone();
            let probe = stream
                .window_average(10, Box::new(NativeWindowBackend))
                .probe_with(move |t, data| {
                    for d in data {
                        out2.borrow_mut().push((*t, *d));
                    }
                });
            for (t, v) in [(1u64, 10u64), (11, 20), (21, 30)] {
                input.advance_to(t);
                input.send(v);
            }
            input.advance_to(100);
            input.close();
            worker.step_while(|| !probe.done());
            let mut v = out.borrow().clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v
        });
        assert_eq!(got, vec![(10, 10.0), (20, 20.0), (30, 30.0)]);
    }

    #[test]
    fn round_up() {
        assert_eq!(round_up_to_multiple(0, 10), 10);
        assert_eq!(round_up_to_multiple(9, 10), 10);
        assert_eq!(round_up_to_multiple(10, 10), 20);
        // Near the top of the domain the window end saturates instead of
        // overflowing (a wrapped end would misfile the data at window 0).
        assert_eq!(round_up_to_multiple(u64::MAX - 3, 10), u64::MAX);
        assert_eq!(round_up_to_multiple(u64::MAX, 10), u64::MAX);
    }

    #[test]
    fn end_of_stream_flushes_final_partial_window() {
        // The stream closes while the last window is still partial: the
        // now-empty frontier must retire it (and drop its token) rather
        // than waiting for an advance that will never come.
        let got = run_window(vec![(5, 6), (21, 4), (23, 8)], 10);
        assert_eq!(got, vec![(10, 6.0), (30, 6.0)]);
    }

    #[test]
    fn end_of_stream_retires_window_saturated_at_max() {
        // Timestamps near u64::MAX saturate to a final window ending at
        // exactly u64::MAX, which the exclusive `..target_ts` retirement
        // range can never reach: only the empty-frontier end-of-stream
        // path closes it. Regression test — this used to hang forever.
        let got = run_window(vec![(5, 6), (u64::MAX - 3, 8)], 10);
        assert_eq!(got, vec![(10, 6.0), (u64::MAX, 8.0)]);
    }
}
