//! No-op operators: the idle dataflow fragments of the paper's §7.3.
//!
//! A token-coordinated no-op forwards data unchanged and holds no tokens,
//! so while the fragment is idle the system advances its frontiers purely
//! inside the tracker — "the system can bypass the operator entirely"
//! (§5.2). The contrast with watermark-coordinated no-ops (which must run
//! for every watermark; see `coordination::watermark`) is Figure 8.

use crate::dataflow::channels::{Data, Pact};
use crate::dataflow::operator::OperatorExt;
use crate::dataflow::stream::Stream;
use crate::progress::timestamp::Timestamp;

/// Chains of pass-through operators.
pub trait NoopExt<T: Timestamp, D: Data> {
    /// One pass-through operator (pipeline pact).
    fn noop(&self) -> Stream<T, D>;

    /// A sequential pipeline of `n` pass-through operators.
    fn noop_chain(&self, n: usize) -> Stream<T, D>;
}

impl<T: Timestamp, D: Data> NoopExt<T, D> for Stream<T, D> {
    fn noop(&self) -> Stream<T, D> {
        self.unary(Pact::Pipeline, "noop", |tok, _info| {
            drop(tok);
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    output.session(&token).give_batch(data);
                }
            }
        })
    }

    fn noop_chain(&self, n: usize) -> Stream<T, D> {
        let mut stream = self.clone();
        for _ in 0..n {
            stream = stream.noop();
        }
        stream
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::probe::ProbeExt;
    use crate::worker::execute::execute_single;

    #[test]
    fn chain_forwards_data_and_frontier() {
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let out = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
            let out2 = out.clone();
            let probe = stream
                .noop_chain(64)
                .probe_with(move |t, data| {
                    for d in data {
                        out2.borrow_mut().push((*t, *d));
                    }
                });
            input.advance_to(1);
            input.send(42);
            input.advance_to(2);
            input.send(43);
            input.close();
            worker.step_while(|| !probe.done());
            let got = out.borrow().clone(); got
        });
        assert_eq!(got, vec![(1, 42), (2, 43)]);
    }

    #[test]
    fn idle_chain_completes_without_data() {
        // No data at all: the chain must still drain to completion (pure
        // frontier propagation through the tracker).
        let steps = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let probe = stream.noop_chain(128).probe();
            input.advance_to(10);
            input.close();
            worker.step_while(|| !probe.done());
            worker.steps()
        });
        // Completion in a handful of steps — NOT hundreds: operators are
        // never scheduled, frontiers advance inside the tracker.
        assert!(steps < 20, "idle chain took {steps} steps");
    }
}
