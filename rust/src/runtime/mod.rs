//! The PJRT runtime: loading and executing the AOT-compiled JAX/Pallas data
//! plane from Rust.
//!
//! `make artifacts` (build-time Python, never on the request path) lowers
//! the Layer-2 computations to HLO *text* under `artifacts/`; this module
//! loads them with `HloModuleProto::from_text_file`, compiles each once on
//! the PJRT CPU client, and exposes typed entry points the dataflow
//! operators call from the hot path.
//!
//! The PJRT client itself lives behind the `xla` cargo feature (the `xla`
//! crate — xla-rs — is not part of the offline dependency set). Without
//! the feature the whole API surface still compiles — manifest parsing and
//! metadata work — but constructing a [`PjrtRuntime`] returns a
//! descriptive [`RuntimeError`], and callers fall back to the native Rust
//! backends.

pub mod aggregator;
pub mod pjrt;

pub use aggregator::{WindowAggregator, XlaWindowBackend};
pub use pjrt::{ArtifactMeta, PjrtRuntime};

/// Error type of the PJRT data plane (a message; PJRT failure modes are
/// not recoverable distinctions for callers).
#[derive(Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    /// Wraps a message.
    pub fn msg(message: impl Into<String>) -> Self {
        RuntimeError(message.into())
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<std::num::ParseIntError> for RuntimeError {
    fn from(e: std::num::ParseIntError) -> Self {
        RuntimeError(format!("invalid integer: {e}"))
    }
}

/// Result alias for the PJRT data plane.
pub type Result<T> = std::result::Result<T, RuntimeError>;
