//! The PJRT runtime: loading and executing the AOT-compiled JAX/Pallas data
//! plane from Rust.
//!
//! `make artifacts` (build-time Python, never on the request path) lowers
//! the Layer-2 computations to HLO *text* under `artifacts/`; this module
//! loads them with `HloModuleProto::from_text_file`, compiles each once on
//! the PJRT CPU client, and exposes typed entry points the dataflow
//! operators call from the hot path.

pub mod aggregator;
pub mod pjrt;

pub use aggregator::{WindowAggregator, XlaWindowBackend};
pub use pjrt::{ArtifactMeta, PjrtRuntime};
