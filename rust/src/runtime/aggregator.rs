//! Typed aggregation entry points over the PJRT runtime.
//!
//! [`WindowAggregator`] adapts arbitrary-size batches to the artifact's
//! static `(N, W)` shape: batches are chunked to `N` lanes (padding with
//! `id = -1`), window keys are mapped to dense slots per call, and the
//! per-slot statistics are mapped back to window keys. Windows with
//! count 0 are dropped (their max/min lanes hold sentinels).
//!
//! [`XlaWindowBackend`] plugs the aggregator into the windowing operators'
//! [`WindowBackend`](crate::operators::window::WindowBackend) hook, giving
//! the dataflow an XLA data plane behind `--agg xla`.

use super::pjrt::PjrtRuntime;
use super::{Result, RuntimeError};
use crate::operators::window::WindowBackend;
use std::collections::BTreeMap;

/// Per-window aggregation results (dense, keyed by caller-provided key).
#[derive(Clone, Debug, PartialEq)]
pub struct WindowStats {
    /// Window key (e.g. end-of-window timestamp).
    pub window: u64,
    /// Sum of values.
    pub sum: f64,
    /// Number of values.
    pub count: u64,
    /// Maximum value.
    pub max: f64,
    /// Minimum value.
    pub min: f64,
}

/// Batched segmented aggregation through one AOT artifact.
pub struct WindowAggregator {
    runtime: PjrtRuntime,
    artifact: String,
    n: usize,
    w: usize,
    /// Scratch buffers reused across calls (hot path: no allocation).
    values_buf: Vec<f32>,
    ids_buf: Vec<i32>,
    executions: u64,
}

impl WindowAggregator {
    /// Opens `artifacts_dir` and prepares artifact `name` (e.g.
    /// `window_agg_1024x64`).
    pub fn new(artifacts_dir: &str, name: &str) -> Result<Self> {
        let mut runtime = PjrtRuntime::new(artifacts_dir)?;
        let meta = runtime.meta(name)?.clone();
        if meta.outputs != 4 {
            return Err(RuntimeError::msg(format!("{name} is not a full-agg artifact")));
        }
        runtime.load(name)?; // compile eagerly, off the hot path
        Ok(WindowAggregator {
            runtime,
            artifact: name.to_string(),
            n: meta.n,
            w: meta.w,
            values_buf: Vec::new(),
            ids_buf: Vec::new(),
            executions: 0,
        })
    }

    /// The artifact's static batch size.
    pub fn batch_size(&self) -> usize {
        self.n
    }

    /// Number of PJRT executions so far (diagnostics / perf accounting).
    pub fn executions(&self) -> u64 {
        self.executions
    }

    /// Aggregates `(window, value)` pairs into per-window statistics.
    ///
    /// Handles arbitrary batch sizes and arbitrary numbers of distinct
    /// windows by chunking to the artifact's `(N, W)` shape.
    pub fn aggregate(&mut self, items: &[(u64, f64)]) -> Result<Vec<WindowStats>> {
        let mut merged: BTreeMap<u64, WindowStats> = BTreeMap::new();
        for chunk in items.chunks(self.n) {
            // Dense slot assignment for this chunk, capped at W windows per
            // execution (overflow spills into additional executions).
            let mut start = 0;
            while start < chunk.len() {
                let mut slots: BTreeMap<u64, usize> = BTreeMap::new();
                let mut end = start;
                while end < chunk.len() {
                    let window = chunk[end].0;
                    if !slots.contains_key(&window) {
                        if slots.len() == self.w {
                            break;
                        }
                        let next = slots.len();
                        slots.insert(window, next);
                    }
                    end += 1;
                }
                self.values_buf.clear();
                self.ids_buf.clear();
                for &(window, value) in &chunk[start..end] {
                    self.values_buf.push(value as f32);
                    self.ids_buf.push(slots[&window] as i32);
                }
                self.values_buf.resize(self.n, 0.0);
                self.ids_buf.resize(self.n, -1);
                let outputs =
                    self.runtime
                        .execute_agg(&self.artifact, &self.values_buf, &self.ids_buf)?;
                self.executions += 1;
                let (sums, counts, maxs, mins) =
                    (&outputs[0], &outputs[1], &outputs[2], &outputs[3]);
                for (&window, &slot) in &slots {
                    let count = counts[slot] as u64;
                    if count == 0 {
                        continue;
                    }
                    let entry = merged.entry(window).or_insert(WindowStats {
                        window,
                        sum: 0.0,
                        count: 0,
                        max: f64::NEG_INFINITY,
                        min: f64::INFINITY,
                    });
                    entry.sum += sums[slot] as f64;
                    entry.count += count;
                    entry.max = entry.max.max(maxs[slot] as f64);
                    entry.min = entry.min.min(mins[slot] as f64);
                }
                start = end;
            }
        }
        Ok(merged.into_values().collect())
    }
}

/// [`WindowBackend`] adapter: the windowing operators' XLA data plane.
pub struct XlaWindowBackend {
    aggregator: WindowAggregator,
    scratch: Vec<(u64, f64)>,
}

impl XlaWindowBackend {
    /// Uses the default full-agg artifact from `artifacts_dir`.
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        Ok(XlaWindowBackend {
            aggregator: WindowAggregator::new(artifacts_dir, "window_agg_1024x64")?,
            scratch: Vec::new(),
        })
    }

    /// Number of PJRT executions so far.
    pub fn executions(&self) -> u64 {
        self.aggregator.executions()
    }
}

impl WindowBackend for XlaWindowBackend {
    fn aggregate(&mut self, items: &[(u64, u64)]) -> Vec<(u64, u64, u64)> {
        self.scratch.clear();
        self.scratch.extend(items.iter().map(|&(w, v)| (w, v as f64)));
        self.aggregator
            .aggregate(&self.scratch)
            .expect("XLA aggregation failed")
            .into_iter()
            .map(|s| (s.window, s.sum as u64, s.count))
            .collect()
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
