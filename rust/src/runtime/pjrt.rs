//! PJRT client wrapper: artifact manifest, lazy compilation, execution.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One entry of `artifacts/manifest.txt` (written by `python -m
/// compile.aot`): the artifact's static shapes and file name.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Artifact name (e.g. `window_agg_1024x64`).
    pub name: String,
    /// Batch size `N` the module was lowered for.
    pub n: usize,
    /// Window-slot count `W`.
    pub w: usize,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
    /// HLO text file, relative to the artifacts directory.
    pub file: String,
}

impl ArtifactMeta {
    /// Parses a manifest line: `name n=.. w=.. outputs=.. file=..`.
    pub fn parse(line: &str) -> Result<ArtifactMeta> {
        let mut parts = line.split_whitespace();
        let name = parts.next().ok_or_else(|| anyhow!("empty manifest line"))?.to_string();
        let mut n = None;
        let mut w = None;
        let mut outputs = None;
        let mut file = None;
        for part in parts {
            let (key, value) =
                part.split_once('=').ok_or_else(|| anyhow!("bad manifest field: {part}"))?;
            match key {
                "n" => n = Some(value.parse()?),
                "w" => w = Some(value.parse()?),
                "outputs" => outputs = Some(value.parse()?),
                "file" => file = Some(value.to_string()),
                other => return Err(anyhow!("unknown manifest key: {other}")),
            }
        }
        Ok(ArtifactMeta {
            name,
            n: n.ok_or_else(|| anyhow!("manifest line missing n"))?,
            w: w.ok_or_else(|| anyhow!("manifest line missing w"))?,
            outputs: outputs.ok_or_else(|| anyhow!("manifest line missing outputs"))?,
            file: file.ok_or_else(|| anyhow!("manifest line missing file"))?,
        })
    }
}

/// A PJRT CPU client plus the compiled executables of the artifact set.
///
/// One runtime per worker thread (PJRT handles are not shared across
/// workers; compilation is once per worker and off the hot path).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ArtifactMeta>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Opens the artifacts directory and reads its manifest.
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let mut manifest = HashMap::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let meta = ArtifactMeta::parse(line)?;
            manifest.insert(meta.name.clone(), meta);
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(PjrtRuntime { client, dir, manifest, executables: HashMap::new() })
    }

    /// Artifact metadata by name.
    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }

    /// Names of all artifacts in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut names: Vec<_> = self.manifest.keys().cloned().collect();
        names.sort();
        names
    }

    /// Compiles (once) and returns the executable for `name`.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let meta = self.meta(name)?.clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let computation = xla::XlaComputation::from_proto(&proto);
            let executable = self
                .client
                .compile(&computation)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.executables.insert(name.to_string(), executable);
        }
        Ok(&self.executables[name])
    }

    /// Executes `name` on f32/i32 input vectors, returning the tuple of f32
    /// output vectors.
    pub fn execute_agg(
        &mut self,
        name: &str,
        values: &[f32],
        ids: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let meta = self.meta(name)?.clone();
        anyhow::ensure!(values.len() == meta.n, "values len {} != n {}", values.len(), meta.n);
        anyhow::ensure!(ids.len() == meta.n, "ids len {} != n {}", ids.len(), meta.n);
        let executable = self.load(name)?;
        let values_lit = xla::Literal::vec1(values);
        let ids_lit = xla::Literal::vec1(ids);
        let result = executable
            .execute::<xla::Literal>(&[values_lit, ids_lit])
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("untupling: {e:?}"))?;
        anyhow::ensure!(parts.len() == meta.outputs, "expected {} outputs", meta.outputs);
        parts
            .iter()
            .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_line_parses() {
        let meta =
            ArtifactMeta::parse("window_agg_1024x64 n=1024 w=64 outputs=4 file=x.hlo.txt")
                .unwrap();
        assert_eq!(meta.n, 1024);
        assert_eq!(meta.w, 64);
        assert_eq!(meta.outputs, 4);
        assert_eq!(meta.file, "x.hlo.txt");
    }

    #[test]
    fn manifest_line_rejects_garbage() {
        assert!(ArtifactMeta::parse("name n=x w=1 outputs=1 file=f").is_err());
        assert!(ArtifactMeta::parse("name w=1 outputs=1 file=f").is_err());
        assert!(ArtifactMeta::parse("").is_err());
    }

    // PJRT-dependent tests live in rust/tests/runtime_roundtrip.rs (they
    // need `make artifacts` to have run).
}
