//! PJRT client wrapper: artifact manifest, lazy compilation, execution.
//!
//! Manifest handling is dependency-free and always available; everything
//! touching the PJRT client is gated behind the `xla` cargo feature (see
//! [`super`] module docs).

use super::{Result, RuntimeError};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One entry of `artifacts/manifest.txt` (written by `python -m
/// compile.aot`): the artifact's static shapes and file name.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Artifact name (e.g. `window_agg_1024x64`).
    pub name: String,
    /// Batch size `N` the module was lowered for.
    pub n: usize,
    /// Window-slot count `W`.
    pub w: usize,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
    /// HLO text file, relative to the artifacts directory.
    pub file: String,
}

impl ArtifactMeta {
    /// Parses a manifest line: `name n=.. w=.. outputs=.. file=..`.
    pub fn parse(line: &str) -> Result<ArtifactMeta> {
        let mut parts = line.split_whitespace();
        let name = parts
            .next()
            .ok_or_else(|| RuntimeError::msg("empty manifest line"))?
            .to_string();
        let mut n = None;
        let mut w = None;
        let mut outputs = None;
        let mut file = None;
        for part in parts {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| RuntimeError::msg(format!("bad manifest field: {part}")))?;
            match key {
                "n" => n = Some(value.parse()?),
                "w" => w = Some(value.parse()?),
                "outputs" => outputs = Some(value.parse()?),
                "file" => file = Some(value.to_string()),
                other => return Err(RuntimeError::msg(format!("unknown manifest key: {other}"))),
            }
        }
        Ok(ArtifactMeta {
            name,
            n: n.ok_or_else(|| RuntimeError::msg("manifest line missing n"))?,
            w: w.ok_or_else(|| RuntimeError::msg("manifest line missing w"))?,
            outputs: outputs.ok_or_else(|| RuntimeError::msg("manifest line missing outputs"))?,
            file: file.ok_or_else(|| RuntimeError::msg("manifest line missing file"))?,
        })
    }
}

/// A PJRT CPU client plus the compiled executables of the artifact set.
///
/// One runtime per worker thread (PJRT handles are not shared across
/// workers; compilation is once per worker and off the hot path).
pub struct PjrtRuntime {
    #[cfg(feature = "xla")]
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ArtifactMeta>,
    #[cfg(feature = "xla")]
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Opens the artifacts directory and reads its manifest.
    ///
    /// Without the `xla` feature this fails with a descriptive error after
    /// validating the manifest (so misconfiguration surfaces first).
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            RuntimeError::msg(format!(
                "reading {}: {e} — run `make artifacts` first",
                manifest_path.display()
            ))
        })?;
        let mut manifest = HashMap::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let meta = ArtifactMeta::parse(line)?;
            manifest.insert(meta.name.clone(), meta);
        }
        #[cfg(feature = "xla")]
        {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| RuntimeError::msg(format!("PJRT CPU client: {e:?}")))?;
            Ok(PjrtRuntime { client, dir, manifest, executables: HashMap::new() })
        }
        #[cfg(not(feature = "xla"))]
        {
            let _ = (dir, manifest);
            Err(RuntimeError::msg(
                "XLA data plane not compiled in: rebuild with `--features xla` \
                 (requires the xla crate; the default build is dependency-free)",
            ))
        }
    }

    /// Artifact metadata by name.
    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .get(name)
            .ok_or_else(|| RuntimeError::msg(format!("artifact {name} not in manifest")))
    }

    /// Names of all artifacts in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut names: Vec<_> = self.manifest.keys().cloned().collect();
        names.sort();
        names
    }

    /// The artifacts directory this runtime reads from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(feature = "xla")]
impl PjrtRuntime {
    /// Compiles (once) and returns the executable for `name`.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let meta = self.meta(name)?.clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| RuntimeError::msg("non-utf8 path"))?,
            )
            .map_err(|e| RuntimeError::msg(format!("parsing {}: {e:?}", path.display())))?;
            let computation = xla::XlaComputation::from_proto(&proto);
            let executable = self
                .client
                .compile(&computation)
                .map_err(|e| RuntimeError::msg(format!("compiling {name}: {e:?}")))?;
            self.executables.insert(name.to_string(), executable);
        }
        Ok(&self.executables[name])
    }

    /// Executes `name` on f32/i32 input vectors, returning the tuple of f32
    /// output vectors.
    pub fn execute_agg(
        &mut self,
        name: &str,
        values: &[f32],
        ids: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let meta = self.meta(name)?.clone();
        if values.len() != meta.n {
            return Err(RuntimeError::msg(format!(
                "values len {} != n {}",
                values.len(),
                meta.n
            )));
        }
        if ids.len() != meta.n {
            return Err(RuntimeError::msg(format!("ids len {} != n {}", ids.len(), meta.n)));
        }
        let executable = self.load(name)?;
        let values_lit = xla::Literal::vec1(values);
        let ids_lit = xla::Literal::vec1(ids);
        let result = executable
            .execute::<xla::Literal>(&[values_lit, ids_lit])
            .map_err(|e| RuntimeError::msg(format!("executing {name}: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| RuntimeError::msg(format!("fetching {name} result: {e:?}")))?;
        let parts =
            result.to_tuple().map_err(|e| RuntimeError::msg(format!("untupling: {e:?}")))?;
        if parts.len() != meta.outputs {
            return Err(RuntimeError::msg(format!("expected {} outputs", meta.outputs)));
        }
        parts
            .iter()
            .map(|lit| {
                lit.to_vec::<f32>().map_err(|e| RuntimeError::msg(format!("to_vec: {e:?}")))
            })
            .collect()
    }
}

/// Stubs keeping the API surface identical without the `xla` feature.
/// Unreachable in practice: [`PjrtRuntime::new`] already fails without it.
#[cfg(not(feature = "xla"))]
impl PjrtRuntime {
    /// Compiles (once) the executable for `name` (stub: always errors).
    pub fn load(&mut self, _name: &str) -> Result<()> {
        Err(RuntimeError::msg("XLA data plane not compiled in (enable the `xla` feature)"))
    }

    /// Executes `name` (stub: always errors).
    pub fn execute_agg(
        &mut self,
        _name: &str,
        _values: &[f32],
        _ids: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        Err(RuntimeError::msg("XLA data plane not compiled in (enable the `xla` feature)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_line_parses() {
        let meta =
            ArtifactMeta::parse("window_agg_1024x64 n=1024 w=64 outputs=4 file=x.hlo.txt")
                .unwrap();
        assert_eq!(meta.n, 1024);
        assert_eq!(meta.w, 64);
        assert_eq!(meta.outputs, 4);
        assert_eq!(meta.file, "x.hlo.txt");
    }

    #[test]
    fn manifest_line_rejects_garbage() {
        assert!(ArtifactMeta::parse("name n=x w=1 outputs=1 file=f").is_err());
        assert!(ArtifactMeta::parse("name w=1 outputs=1 file=f").is_err());
        assert!(ArtifactMeta::parse("").is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        // Point at a real manifest-free dir: the error must be about the
        // manifest, not a panic; with a manifest it must name the feature.
        let err = PjrtRuntime::new("/nonexistent-artifacts-dir").unwrap_err();
        assert!(err.to_string().contains("manifest"), "{err}");
    }

    // PJRT-dependent tests live in rust/tests/runtime_roundtrip.rs (they
    // need `make artifacts` to have run).
}
