//! Bounded SPSC FIFO rings: the one transport primitive under both fabric
//! planes.
//!
//! Every `(channel, from, to)` endpoint pair the [`Fabric`] hands out —
//! progress mailboxes and data channels alike — is one of these rings: a
//! fixed-capacity Lamport queue with exactly one producer and one consumer.
//! Both sides run wait-free: the producer owns the tail index, the consumer
//! owns the head index, each publishes its index with a `Release` store and
//! reads the other's with an `Acquire` load (cached locally and refreshed
//! only when the ring looks full/empty, so the steady state touches one
//! cache line per side). There are no locks to convoy on and no allocation
//! per message — the `std::sync::mpsc` pairs this replaces took a mutex on
//! every send *and* allocated a node per message.
//!
//! A full ring rejects the push (`RingSendError::Full`) instead of
//! blocking: callers keep the message staged and retry after peers drain
//! (see `ChannelSend::flush_remote` and `Progcaster`'s spill queue), which
//! keeps the whole fabric deadlock-free by construction. Disconnects are
//! detected through a shared `closed` flag set when either endpoint drops.
//!
//! [`Fabric`]: super::allocator::Fabric

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::TryRecvError;
use std::sync::Arc;

/// Why a [`RingSender::send`] was rejected; the message is handed back.
pub enum RingSendError<M> {
    /// The ring is at capacity; retry after the consumer drains.
    Full(M),
    /// The receiving endpoint was dropped; the message cannot arrive.
    Disconnected(M),
}

impl<M> RingSendError<M> {
    /// Recovers the rejected message.
    pub fn into_inner(self) -> M {
        match self {
            RingSendError::Full(m) | RingSendError::Disconnected(m) => m,
        }
    }
}

impl<M> std::fmt::Debug for RingSendError<M> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        match self {
            RingSendError::Full(_) => write!(f, "RingSendError::Full(..)"),
            RingSendError::Disconnected(_) => write!(f, "RingSendError::Disconnected(..)"),
        }
    }
}

/// The storage shared by the two endpoints.
struct Shared<M> {
    /// Power-of-two slot array; index `i` lives at `slots[i & mask]`.
    slots: Box<[UnsafeCell<MaybeUninit<M>>]>,
    mask: usize,
    /// Next slot the producer will write (monotonic, never wrapped).
    tail: AtomicUsize,
    /// Next slot the consumer will read (monotonic, never wrapped).
    head: AtomicUsize,
    /// Set when either endpoint drops.
    closed: AtomicBool,
}

// SAFETY: slot `i` is written exactly once by the single producer before it
// publishes `tail = i + 1` (Release), and read exactly once by the single
// consumer after observing `tail > i` (Acquire); the consumer then
// publishes `head = i + 1`, after which the producer may reuse the slot —
// again through an Acquire load of `head`. No slot is ever accessed by both
// sides between the same pair of index publications.
unsafe impl<M: Send> Send for Shared<M> {}
unsafe impl<M: Send> Sync for Shared<M> {}

impl<M> Drop for Shared<M> {
    fn drop(&mut self) {
        // Both endpoints are gone (`Arc` exclusivity): drop the messages
        // still sitting between head and tail.
        let head = *self.head.get_mut();
        let tail = *self.tail.get_mut();
        for i in head..tail {
            // SAFETY: slots in [head, tail) hold initialized, unconsumed
            // messages, each visited exactly once.
            unsafe { (*self.slots[i & self.mask].get()).assume_init_drop() };
        }
    }
}

/// The producing endpoint of an SPSC ring. Not cloneable: single producer.
pub struct RingSender<M> {
    shared: Arc<Shared<M>>,
    /// Producer-local copy of `tail` (authoritative between publications).
    tail: usize,
    /// Last observed consumer head (refreshed only when the ring looks full).
    head_cache: usize,
}

/// The consuming endpoint of an SPSC ring. Not cloneable: single consumer.
pub struct RingReceiver<M> {
    shared: Arc<Shared<M>>,
    /// Consumer-local copy of `head` (authoritative between publications).
    head: usize,
    /// Last observed producer tail (refreshed only when the ring looks empty).
    tail_cache: usize,
}

/// Creates an SPSC ring holding at least `capacity` messages (rounded up to
/// a power of two, minimum 2).
pub fn channel<M: Send>(capacity: usize) -> (RingSender<M>, RingReceiver<M>) {
    let capacity = capacity.max(2).next_power_of_two();
    let shared = Arc::new(Shared {
        slots: (0..capacity).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect(),
        mask: capacity - 1,
        tail: AtomicUsize::new(0),
        head: AtomicUsize::new(0),
        closed: AtomicBool::new(false),
    });
    (
        RingSender { shared: shared.clone(), tail: 0, head_cache: 0 },
        RingReceiver { shared, head: 0, tail_cache: 0 },
    )
}

impl<M: Send> RingSender<M> {
    /// The fixed capacity of the ring.
    pub fn capacity(&self) -> usize {
        self.shared.mask + 1
    }

    /// Pushes `m`, or hands it back if the ring is full or the receiver is
    /// gone. Wait-free; never blocks.
    pub fn send(&mut self, m: M) -> Result<(), RingSendError<M>> {
        if self.shared.closed.load(Ordering::Acquire) {
            return Err(RingSendError::Disconnected(m));
        }
        let capacity = self.shared.mask + 1;
        if self.tail - self.head_cache == capacity {
            self.head_cache = self.shared.head.load(Ordering::Acquire);
            if self.tail - self.head_cache == capacity {
                return Err(RingSendError::Full(m));
            }
        }
        // SAFETY: `tail - head >= capacity` was just excluded, so the slot
        // at `tail` has been consumed (or never used); the single producer
        // writes it before publishing the new tail.
        unsafe { (*self.shared.slots[self.tail & self.shared.mask].get()).write(m) };
        self.tail += 1;
        self.shared.tail.store(self.tail, Ordering::Release);
        Ok(())
    }
}

impl<M> Drop for RingSender<M> {
    fn drop(&mut self) {
        self.shared.closed.store(true, Ordering::Release);
    }
}

impl<M: Send> RingReceiver<M> {
    /// Pops the next message, mirroring `std::sync::mpsc::Receiver::try_recv`
    /// semantics: `Empty` when the ring is (currently) drained,
    /// `Disconnected` once it is drained *and* the sender is gone.
    pub fn try_recv(&mut self) -> Result<M, TryRecvError> {
        if self.head == self.tail_cache {
            self.tail_cache = self.shared.tail.load(Ordering::Acquire);
            if self.head == self.tail_cache {
                // Check closed *after* the tail re-load: a sender that
                // pushed then dropped publishes tail before closed, so a
                // Disconnected verdict can never hide a delivered message.
                if self.shared.closed.load(Ordering::Acquire) {
                    let tail = self.shared.tail.load(Ordering::Acquire);
                    if tail == self.head {
                        return Err(TryRecvError::Disconnected);
                    }
                    self.tail_cache = tail;
                } else {
                    return Err(TryRecvError::Empty);
                }
            }
        }
        // SAFETY: `tail > head`, so the slot at `head` holds an initialized
        // message the single consumer has not yet read.
        let slot = self.shared.slots[self.head & self.shared.mask].get();
        let m = unsafe { (*slot).assume_init_read() };
        self.head += 1;
        self.shared.head.store(self.head, Ordering::Release);
        Ok(m)
    }

    /// Blocking receive by spinning on [`try_recv`](RingReceiver::try_recv)
    /// with yields — a convenience for tests and shutdown paths, not the
    /// hot path (workers park instead; see the worker step loop).
    pub fn recv(&mut self) -> Result<M, TryRecvError> {
        loop {
            match self.try_recv() {
                Ok(m) => return Ok(m),
                Err(TryRecvError::Disconnected) => return Err(TryRecvError::Disconnected),
                Err(TryRecvError::Empty) => std::thread::yield_now(),
            }
        }
    }
}

impl<M> Drop for RingReceiver<M> {
    fn drop(&mut self) {
        self.shared.closed.store(true, Ordering::Release);
        // Unconsumed messages are dropped by `Shared::drop` once the
        // sender's handle is gone too.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_same_thread() {
        let (mut tx, mut rx) = channel::<u64>(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.try_recv().unwrap(), i);
        }
        assert!(matches!(rx.try_recv(), Err(TryRecvError::Empty)));
    }

    #[test]
    fn full_ring_rejects_and_recovers() {
        let (mut tx, mut rx) = channel::<u64>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        match tx.send(3) {
            Err(RingSendError::Full(m)) => assert_eq!(m, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(rx.try_recv().unwrap(), 1);
        tx.send(3).unwrap();
        assert_eq!(rx.try_recv().unwrap(), 2);
        assert_eq!(rx.try_recv().unwrap(), 3);
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        let (tx, _rx) = channel::<u8>(100);
        assert_eq!(tx.capacity(), 128);
        let (tx, _rx) = channel::<u8>(0);
        assert_eq!(tx.capacity(), 2);
    }

    #[test]
    fn receiver_drop_disconnects_sender() {
        let (mut tx, rx) = channel::<u64>(4);
        drop(rx);
        assert!(matches!(tx.send(1), Err(RingSendError::Disconnected(1))));
    }

    #[test]
    fn sender_drop_yields_disconnected_after_drain() {
        let (mut tx, mut rx) = channel::<u64>(4);
        tx.send(7).unwrap();
        drop(tx);
        // The in-flight message is still delivered...
        assert_eq!(rx.try_recv().unwrap(), 7);
        // ...and only then does the receiver see the disconnect.
        assert!(matches!(rx.try_recv(), Err(TryRecvError::Disconnected)));
    }

    #[test]
    fn messages_dropped_with_ring_are_freed() {
        // Rc-free leak check via a counting guard.
        use std::sync::atomic::AtomicUsize;
        static LIVE: AtomicUsize = AtomicUsize::new(0);
        struct Guard;
        impl Guard {
            fn new() -> Self {
                LIVE.fetch_add(1, Ordering::SeqCst);
                Guard
            }
        }
        impl Drop for Guard {
            fn drop(&mut self) {
                LIVE.fetch_sub(1, Ordering::SeqCst);
            }
        }
        let (mut tx, rx) = channel::<Guard>(8);
        tx.send(Guard::new()).unwrap();
        tx.send(Guard::new()).unwrap();
        assert_eq!(LIVE.load(Ordering::SeqCst), 2);
        drop(tx);
        drop(rx);
        assert_eq!(LIVE.load(Ordering::SeqCst), 0, "undelivered messages must drop");
    }

    #[test]
    fn cross_thread_fifo_under_backpressure() {
        let (mut tx, mut rx) = channel::<u64>(4);
        let producer = std::thread::spawn(move || {
            for i in 0..10_000u64 {
                let mut m = i;
                loop {
                    match tx.send(m) {
                        Ok(()) => break,
                        Err(RingSendError::Full(back)) => {
                            m = back;
                            std::thread::yield_now();
                        }
                        Err(RingSendError::Disconnected(_)) => panic!("receiver vanished"),
                    }
                }
            }
        });
        for expect in 0..10_000u64 {
            assert_eq!(rx.recv().unwrap(), expect, "FIFO order violated");
        }
        producer.join().unwrap();
        assert!(matches!(rx.recv(), Err(TryRecvError::Disconnected)));
    }
}
