//! Launching a multi-worker computation — in one process or across many.
//!
//! `execute(config, build)` spawns one thread per worker (optionally pinned
//! to physical cores, as in the paper's §7.1 setup), runs the same
//! construction-and-driving closure on each, and returns the per-worker
//! results in index order. Workers share only the communication fabric;
//! each claims its own progress mailboxes from it (there is no central
//! progress structure to hand out).
//!
//! `execute_cluster(config, build)` extends the same model across
//! processes: every process runs the same binary with the same `build`
//! closure and a `Config { processes, process_index, addresses }` naming
//! the cluster. Bootstrap is a full TCP mesh — process `p` listens on
//! `addresses[p]`, connects to every lower-indexed process (with retry,
//! so start order is free), and accepts the rest — with a versioned
//! handshake that (a) verifies both sides agree on the cluster shape —
//! the full per-process worker-count vector, so heterogeneous clusters
//! (`Config::cluster_shape`, e.g. 2+1+1) validate end to end — and
//! (b) propagates process 0's tuning (`ring_capacity`, `progress_flush`,
//! `send_batch`) to every process, so one process's flags configure the
//! whole cluster. Worker indices are global, in contiguous per-process
//! blocks of possibly unequal size; the per-process `Fabric` routes
//! channels between them over rings or the serializing net fabric
//! transparently. Shutdown is
//! orderly: workers flush on exit (`Worker::flush_now` runs on drop), the
//! net fabric drains its outbound queues and closes write sides, and
//! peers observe clean end-of-stream.

use super::allocator::Fabric;
use super::Worker;
use crate::config::Config;
use crate::net::fabric::NetFabric;
use crate::net::transport::{tcp_pair, Link, NetError};
use crate::progress::timestamp::Timestamp;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Pins the calling thread to core `index` (best-effort; ignored if the
/// affinity call fails, e.g. in restricted containers).
///
/// Compiled only with the `affinity` feature, which expects the `libc`
/// crate to be added to the build (the default build keeps the dependency
/// set empty so it resolves fully offline).
#[cfg(feature = "affinity")]
pub fn pin_to_core(index: usize) {
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_ZERO(&mut set);
        let cores = libc::sysconf(libc::_SC_NPROCESSORS_ONLN) as usize;
        if cores > 0 {
            libc::CPU_SET(index % cores, &mut set);
            let _ = libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set);
        }
    }
}

/// No-op fallback: core pinning requires the `affinity` feature.
#[cfg(not(feature = "affinity"))]
pub fn pin_to_core(_index: usize) {}

/// Runs `build` on `config.workers` worker threads; each invocation builds
/// the (identical) dataflow and drives its worker. Returns each worker's
/// result, in worker-index order.
pub fn execute<T, R, F>(config: Config, build: F) -> Vec<R>
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    execute_inner(config, build).0
}

/// [`execute`]'s body, additionally handing back the shared fabric so
/// callers can snapshot telemetry after every worker has finished.
fn execute_inner<T, R, F>(config: Config, build: F) -> (Vec<R>, Arc<Fabric>)
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    let peers = config.workers.max(1);
    let fabric = Fabric::with_ring_capacity(peers, config.ring_capacity);
    let build = Arc::new(build);
    let pin = config.pin_workers;
    let progress_flush = config.progress_flush;
    let send_batch = config.send_batch;

    let mut handles = Vec::with_capacity(peers);
    for index in 0..peers {
        let fabric = fabric.clone();
        let build = build.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("worker-{index}"))
                .spawn(move || {
                    if pin {
                        pin_to_core(index);
                    }
                    let mut worker = Worker::new(index, peers, fabric);
                    worker.set_progress_flush(progress_flush);
                    worker.set_send_batch(send_batch);
                    build(&mut worker)
                })
                .expect("spawn worker thread"),
        );
    }
    let results = handles
        .into_iter()
        .map(|h| h.join().expect("worker thread panicked"))
        .collect();
    (results, fabric)
}

/// Single-worker convenience wrapper: returns the sole worker's result.
pub fn execute_single<T, R, F>(build: F) -> R
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    execute(Config { workers: 1, ..Config::default() }, build)
        .pop()
        .expect("one worker")
}

// ---------------------------------------------------------------------------
// Cluster execution.
// ---------------------------------------------------------------------------

/// Handshake magic: "ttdnetv1" as little-endian bytes.
const HANDSHAKE_MAGIC: u64 = u64::from_le_bytes(*b"ttdnetv1");

/// Bumped whenever the wire format or handshake layout changes.
/// Version 2: per-process broadcast progress frames (dedup fan-out), and
/// the handshake carries the full per-process worker-count shape so
/// heterogeneous clusters (e.g. 2+1+1) validate end to end.
const HANDSHAKE_VERSION: u32 = 2;

/// How long bootstrap keeps retrying a refused connection (peers may not
/// be listening yet; start order is free).
const CONNECT_RETRY_FOR: Duration = Duration::from_secs(30);

/// Reads and validates the shape vector trailing a handshake record: the
/// peer's per-process worker counts must equal `shape` exactly.
fn read_shape(stream: &mut TcpStream, shape: &[usize]) -> Result<(), NetError> {
    let mut buf = vec![0u8; 4 * shape.len()];
    stream.read_exact(&mut buf)?;
    for (p, expected) in shape.iter().enumerate() {
        let got =
            u32::from_le_bytes(buf[4 * p..4 * p + 4].try_into().expect("4 bytes")) as usize;
        if got != *expected {
            return Err(NetError::Protocol(format!(
                "cluster shape mismatch at process {p}: peer says {got} workers, \
                 local config says {expected}"
            )));
        }
    }
    Ok(())
}

/// Appends the shape vector (`u32` per process) to a handshake buffer.
fn push_shape(buf: &mut Vec<u8>, shape: &[usize]) {
    for workers in shape {
        buf.extend_from_slice(&(*workers as u32).to_le_bytes());
    }
}

/// `HELLO` (connector → acceptor): magic, version, sender, process count,
/// then the full per-process worker shape. All little-endian.
fn write_hello(stream: &mut TcpStream, config: &Config, shape: &[usize]) -> Result<(), NetError> {
    let mut buf = Vec::with_capacity(20 + 4 * shape.len());
    buf.extend_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    buf.extend_from_slice(&HANDSHAKE_VERSION.to_le_bytes());
    buf.extend_from_slice(&(config.process_index as u32).to_le_bytes());
    buf.extend_from_slice(&(config.processes as u32).to_le_bytes());
    push_shape(&mut buf, shape);
    stream.write_all(&buf)?;
    stream.flush()?;
    Ok(())
}

/// Reads and validates a `HELLO`, returning the connecting process index.
fn read_hello(
    stream: &mut TcpStream,
    config: &Config,
    shape: &[usize],
) -> Result<usize, NetError> {
    let mut buf = [0u8; 20];
    stream.read_exact(&mut buf)?;
    let magic = u64::from_le_bytes(buf[0..8].try_into().expect("8 bytes"));
    let version = u32::from_le_bytes(buf[8..12].try_into().expect("4 bytes"));
    let process = u32::from_le_bytes(buf[12..16].try_into().expect("4 bytes")) as usize;
    let processes = u32::from_le_bytes(buf[16..20].try_into().expect("4 bytes")) as usize;
    if magic != HANDSHAKE_MAGIC {
        return Err(NetError::Protocol("bad magic (not a ttd peer?)".into()));
    }
    if version != HANDSHAKE_VERSION {
        return Err(NetError::Protocol(format!(
            "wire version mismatch: peer {version}, local {HANDSHAKE_VERSION}"
        )));
    }
    if processes != config.processes {
        return Err(NetError::Protocol(format!(
            "cluster shape mismatch: peer says {processes} processes, local config says {}",
            config.processes
        )));
    }
    read_shape(stream, shape)?;
    if process >= processes {
        return Err(NetError::Protocol(format!("peer index {process} out of range")));
    }
    Ok(process)
}

/// `WELCOME` (acceptor → connector): echoes the cluster identity, carries
/// the acceptor's tuning, then the shape. The connector adopts the tuning
/// only from process 0, which makes process 0's flags authoritative for
/// the whole cluster (every process connects to 0 before spawning
/// workers).
fn write_welcome(stream: &mut TcpStream, config: &Config, shape: &[usize]) -> Result<(), NetError> {
    let mut buf = Vec::with_capacity(44 + 4 * shape.len());
    buf.extend_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    buf.extend_from_slice(&HANDSHAKE_VERSION.to_le_bytes());
    buf.extend_from_slice(&(config.process_index as u32).to_le_bytes());
    buf.extend_from_slice(&(config.processes as u32).to_le_bytes());
    buf.extend_from_slice(&(config.ring_capacity as u64).to_le_bytes());
    buf.extend_from_slice(&(config.progress_flush.as_nanos() as u64).to_le_bytes());
    buf.extend_from_slice(&(config.send_batch as u64).to_le_bytes());
    push_shape(&mut buf, shape);
    stream.write_all(&buf)?;
    stream.flush()?;
    Ok(())
}

/// Reads a `WELCOME`; if it came from process 0, adopts its tuning into
/// the local config (the "config propagation" half of the handshake).
fn read_welcome(
    stream: &mut TcpStream,
    config: &mut Config,
    shape: &[usize],
    peer: usize,
) -> Result<(), NetError> {
    let mut buf = [0u8; 44];
    stream.read_exact(&mut buf)?;
    let magic = u64::from_le_bytes(buf[0..8].try_into().expect("8 bytes"));
    let version = u32::from_le_bytes(buf[8..12].try_into().expect("4 bytes"));
    let process = u32::from_le_bytes(buf[12..16].try_into().expect("4 bytes")) as usize;
    if magic != HANDSHAKE_MAGIC || version != HANDSHAKE_VERSION {
        return Err(NetError::Protocol("bad welcome".into()));
    }
    if process != peer {
        return Err(NetError::Protocol(format!(
            "connected to {peer} but process {process} answered (address list skew?)"
        )));
    }
    if peer == 0 {
        config.ring_capacity =
            u64::from_le_bytes(buf[20..28].try_into().expect("8 bytes")) as usize;
        config.progress_flush = Duration::from_nanos(u64::from_le_bytes(
            buf[28..36].try_into().expect("8 bytes"),
        ));
        config.send_batch = u64::from_le_bytes(buf[36..44].try_into().expect("8 bytes")) as usize;
    }
    read_shape(stream, shape)?;
    Ok(())
}

/// Connects to `address` with retry (the peer may not be listening yet).
fn connect_with_retry(address: &str) -> Result<TcpStream, NetError> {
    let deadline = Instant::now() + CONNECT_RETRY_FOR;
    loop {
        match TcpStream::connect(address) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(NetError::Protocol(format!(
                        "could not reach peer at {address} within {CONNECT_RETRY_FOR:?}: {e}"
                    )));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Establishes the full mesh for `config` (whose cluster shape is
/// `shape`), returning one transport pair per process (`None` at
/// `config.process_index`) and adopting process 0's tuning into `config`.
fn bootstrap(
    config: &mut Config,
    shape: &[usize],
) -> Result<Vec<Option<Link>>, NetError> {
    let me = config.process_index;
    let processes = config.processes;
    if config.addresses.len() != processes {
        return Err(NetError::Protocol(format!(
            "need one address per process: got {} for {processes} processes",
            config.addresses.len()
        )));
    }
    let listener = TcpListener::bind(&config.addresses[me]).map_err(|e| {
        NetError::Protocol(format!("cannot listen on {}: {e}", config.addresses[me]))
    })?;

    let mut links: Vec<Option<Link>> =
        (0..processes).map(|_| None).collect();

    // Connect to every lower-indexed process, in order — 0 first, so its
    // WELCOME configures this process before anything else happens.
    for peer in 0..me {
        let mut stream = connect_with_retry(&config.addresses[peer])?;
        // Bound the reply read: a wedged peer (or an unrelated service on
        // the address) must fail the bootstrap, not hang it.
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        write_hello(&mut stream, config, shape)?;
        read_welcome(&mut stream, config, shape, peer)?;
        let _ = stream.set_read_timeout(None);
        let (tx, rx) = tcp_pair(stream)?;
        links[peer] = Some((Box::new(tx), Box::new(rx)));
    }

    // Accept every higher-indexed process, identified by its HELLO.
    let mut expected: usize = processes - 1 - me;
    while expected > 0 {
        let (mut stream, _addr) = listener.accept()?;
        // Bound the handshake read so a silent stray connection cannot
        // wedge the accept loop.
        let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
        let peer = match read_hello(&mut stream, config, shape) {
            Ok(peer) => peer,
            // A stray or dying connection (port scanner, crashed peer
            // retrying) must not wedge the bootstrap: drop it and keep
            // accepting. Real misconfigurations surface as Protocol.
            Err(NetError::Io(_)) => continue,
            Err(e) => return Err(e),
        };
        let _ = stream.set_read_timeout(None);
        if peer <= me || links[peer].is_some() {
            return Err(NetError::Protocol(format!("unexpected connection from {peer}")));
        }
        write_welcome(&mut stream, config, shape)?;
        let (tx, rx) = tcp_pair(stream)?;
        links[peer] = Some((Box::new(tx), Box::new(rx)));
        expected -= 1;
    }
    Ok(links)
}

/// Runs `build` on every worker this process hosts, as part of a
/// `config.processes`-process cluster (every process must call this with
/// the same cluster shape and its own `process_index`). The shape may be
/// heterogeneous: `config.cluster_shape` gives per-process worker counts
/// (empty = `config.workers` everywhere). Returns the *local* workers'
/// results, in global index order. With `processes <= 1` this is exactly
/// [`execute`].
pub fn execute_cluster<T, R, F>(config: Config, build: F) -> Result<Vec<R>, NetError>
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    execute_cluster_telemetry(config, build).map(|(results, _telemetry)| results)
}

/// [`execute_cluster`] plus the local workers' fabric telemetry, in
/// global index order, snapshotted AFTER the net fabric's shutdown — by
/// then every peer's stream has reached end-of-stream and every inbound
/// frame has been demuxed (broadcast frames fanned out), so cross-process
/// counter relations (e.g. the broadcast-dedup frame/delivery ratio the
/// cluster tests assert) are exact rather than racing in-flight frames.
pub fn execute_cluster_telemetry<T, R, F>(
    config: Config,
    build: F,
) -> Result<(Vec<R>, Vec<crate::worker::allocator::WorkerTelemetry>), NetError>
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    if config.processes <= 1 {
        let (results, fabric) = execute_inner(config, build);
        let telemetry = fabric.telemetry_all();
        return Ok((results, telemetry));
    }
    let mut config = config;
    let shape = config.shape();
    if shape.len() != config.processes {
        return Err(NetError::Protocol(format!(
            "cluster_shape names {} processes but config.processes is {}",
            shape.len(),
            config.processes
        )));
    }
    config.workers = shape[config.process_index];
    let links = bootstrap(&mut config, &shape)?;

    let process = config.process_index;
    let local_workers = shape[process];
    let net = NetFabric::new(process, shape.clone(), links, config.ring_capacity);
    let fabric = Fabric::cluster(&shape, process, config.ring_capacity, net.clone());
    let peers = fabric.peers();
    let base = fabric.local_base();
    let build = Arc::new(build);
    let pin = config.pin_workers;
    let progress_flush = config.progress_flush;
    let send_batch = config.send_batch;

    let mut handles = Vec::with_capacity(local_workers);
    for local in 0..local_workers {
        let fabric = fabric.clone();
        let build = build.clone();
        let index = base + local;
        handles.push(
            std::thread::Builder::new()
                .name(format!("worker-{index}"))
                .spawn(move || {
                    if pin {
                        pin_to_core(local);
                    }
                    let mut worker = Worker::new(index, peers, fabric);
                    worker.set_progress_flush(progress_flush);
                    worker.set_send_batch(send_batch);
                    build(&mut worker)
                })
                .expect("spawn worker thread"),
        );
    }
    let results: Vec<R> = handles
        .into_iter()
        .map(|h| h.join().expect("worker thread panicked"))
        .collect();
    // Every local worker has completed (and flushed, via `Worker::drop`):
    // drain the outbound queues to the wire and close the links cleanly.
    net.shutdown();
    let telemetry = (base..base + local_workers).map(|w| fabric.telemetry(w)).collect();
    Ok((results, telemetry))
}
