//! Launching a multi-worker computation.
//!
//! `execute(config, build)` spawns one thread per worker (optionally pinned
//! to physical cores, as in the paper's §7.1 setup), runs the same
//! construction-and-driving closure on each, and returns the per-worker
//! results in index order. Workers share only the communication fabric;
//! each claims its own progress mailboxes from it (there is no central
//! progress structure to hand out).

use super::allocator::Fabric;
use super::Worker;
use crate::config::Config;
use crate::progress::timestamp::Timestamp;
use std::sync::Arc;

/// Pins the calling thread to core `index` (best-effort; ignored if the
/// affinity call fails, e.g. in restricted containers).
///
/// Compiled only with the `affinity` feature, which expects the `libc`
/// crate to be added to the build (the default build keeps the dependency
/// set empty so it resolves fully offline).
#[cfg(feature = "affinity")]
pub fn pin_to_core(index: usize) {
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_ZERO(&mut set);
        let cores = libc::sysconf(libc::_SC_NPROCESSORS_ONLN) as usize;
        if cores > 0 {
            libc::CPU_SET(index % cores, &mut set);
            let _ = libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set);
        }
    }
}

/// No-op fallback: core pinning requires the `affinity` feature.
#[cfg(not(feature = "affinity"))]
pub fn pin_to_core(_index: usize) {}

/// Runs `build` on `config.workers` worker threads; each invocation builds
/// the (identical) dataflow and drives its worker. Returns each worker's
/// result, in worker-index order.
pub fn execute<T, R, F>(config: Config, build: F) -> Vec<R>
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    let peers = config.workers.max(1);
    let fabric = Fabric::with_ring_capacity(peers, config.ring_capacity);
    let build = Arc::new(build);
    let pin = config.pin_workers;
    let progress_flush = config.progress_flush;
    let send_batch = config.send_batch;

    let mut handles = Vec::with_capacity(peers);
    for index in 0..peers {
        let fabric = fabric.clone();
        let build = build.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("worker-{index}"))
                .spawn(move || {
                    if pin {
                        pin_to_core(index);
                    }
                    let mut worker = Worker::new(index, peers, fabric);
                    worker.set_progress_flush(progress_flush);
                    worker.set_send_batch(send_batch);
                    build(&mut worker)
                })
                .expect("spawn worker thread"),
        );
    }
    handles
        .into_iter()
        .map(|h| h.join().expect("worker thread panicked"))
        .collect()
}

/// Single-worker convenience wrapper: returns the sole worker's result.
pub fn execute_single<T, R, F>(build: F) -> R
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    execute(Config { workers: 1, ..Config::default() }, build)
        .pop()
        .expect("one worker")
}
