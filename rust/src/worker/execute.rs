//! Launching a multi-worker computation — in one process or across many.
//!
//! `execute(config, build)` spawns one thread per worker (optionally pinned
//! to physical cores, as in the paper's §7.1 setup), runs the same
//! construction-and-driving closure on each, and returns the per-worker
//! results in index order. Workers share only the communication fabric;
//! each claims its own progress mailboxes from it (there is no central
//! progress structure to hand out).
//!
//! `execute_cluster(config, build)` extends the same model across
//! processes: every process runs the same binary with the same `build`
//! closure and a `Config { processes, process_index, addresses }` naming
//! the cluster. Bootstrap is a full TCP mesh — process `p` listens on
//! `addresses[p]`, connects to every lower-indexed process (with retry,
//! so start order is free), and accepts the rest — with a versioned
//! handshake that (a) verifies both sides agree on the cluster shape —
//! the full per-process worker-count vector, so heterogeneous clusters
//! (`Config::cluster_shape`, e.g. 2+1+1) validate end to end —
//! (b) propagates process 0's tuning (`ring_capacity`, `progress_flush`,
//! `send_batch`) to every process, so one process's flags configure the
//! whole cluster, and (c) pins both sides to the same per-link transport
//! ([`crate::config::NetTransport`]): reactor-driven nonblocking TCP, a
//! `/dev/shm` byte-ring pair for co-located processes (the bootstrap
//! connection is retained as the parking doorbell), or the legacy
//! blocking thread-pair baseline. `Auto` — the default — selects shared
//! memory exactly when both endpoints' addresses are loopback. Worker
//! indices are global, in contiguous per-process blocks of possibly
//! unequal size; the per-process `Fabric` routes channels between them
//! over rings or the serializing net fabric transparently. Shutdown is
//! orderly: workers flush on exit (`Worker::flush_now` runs on drop), the
//! net fabric drains its outbound queues and closes write sides, and
//! peers observe clean end-of-stream.

use super::allocator::Fabric;
use super::Worker;
use crate::config::{Config, NetTransport, Parking};
use crate::net::fabric::{FabricOptions, NetFabric, NetLink};
use crate::net::reactor::futex_supported;
use crate::net::shm::{
    create_ring, create_wake_word, open_ring, open_wake_word, ShmConsumer, ShmLink, WakeWord,
    SHM_RING_BYTES,
};
use crate::net::transport::{tcp_pair, NetError};
use crate::net::tune::TuneShared;
use crate::progress::timestamp::Timestamp;
use crate::recovery::{CheckpointWriter, RecoveryContext, RestoreBundle, WriteJob};
use std::any::TypeId;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Pins the calling thread to core `index` (best-effort; ignored if the
/// affinity call fails, e.g. in restricted containers).
///
/// Compiled only with the `affinity` feature, which expects the `libc`
/// crate to be added to the build (the default build keeps the dependency
/// set empty so it resolves fully offline).
#[cfg(feature = "affinity")]
pub fn pin_to_core(index: usize) {
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_ZERO(&mut set);
        let cores = libc::sysconf(libc::_SC_NPROCESSORS_ONLN) as usize;
        if cores > 0 {
            libc::CPU_SET(index % cores, &mut set);
            let _ = libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set);
        }
    }
}

/// No-op fallback: core pinning requires the `affinity` feature.
#[cfg(not(feature = "affinity"))]
pub fn pin_to_core(_index: usize) {}

// ---------------------------------------------------------------------------
// Checkpoint / recovery plumbing.
// ---------------------------------------------------------------------------

/// The `Send + Clone` slice of a process's checkpoint configuration that
/// crosses into every worker thread; each thread builds its own
/// (deliberately non-`Send`, `Rc`-shared) [`RecoveryContext`] from it.
#[derive(Clone)]
struct RecoverySetup {
    /// Checkpoint boundary spacing in epochs (0 = restore-only).
    interval: u64,
    /// Job channel into the process's [`CheckpointWriter`].
    writer: Option<Sender<WriteJob>>,
    /// The checkpoint every worker restores from (None = fresh start).
    restore: Option<Arc<RestoreBundle>>,
}

/// Builds the per-process checkpoint machinery `config` asks for: loads
/// the newest complete checkpoint when `config.recover` is set, and spawns
/// the background [`CheckpointWriter`] when a capture interval is
/// configured. Returns `(None, None)` when checkpointing is disabled.
///
/// Checkpoint alignment runs on the `u64` epoch timeline, so a
/// checkpoint-configured launch of a dataflow over any other timestamp
/// type is a misconfiguration and panics here, at launch, rather than
/// silently never capturing.
fn recovery_plumbing<T: Timestamp>(
    config: &Config,
    process: usize,
    local_workers: usize,
    shape: &[usize],
) -> (Option<CheckpointWriter>, Option<RecoverySetup>) {
    let Some(dir) = config.checkpoint_dir.as_deref() else {
        return (None, None);
    };
    if config.checkpoint_interval == 0 && !config.recover {
        return (None, None);
    }
    assert!(
        TypeId::of::<T>() == TypeId::of::<u64>(),
        "checkpointing requires u64 timestamps (checkpoint boundaries are epochs)"
    );
    let restore = if config.recover {
        let bundle = crate::recovery::load_latest(Path::new(dir))
            .unwrap_or_else(|e| panic!("cannot read checkpoint directory {dir}: {e}"))
            .unwrap_or_else(|| panic!("--recover: no complete checkpoint in {dir}"));
        Some(Arc::new(bundle))
    } else {
        None
    };
    let writer = if config.checkpoint_interval > 0 {
        Some(
            CheckpointWriter::spawn(
                PathBuf::from(dir),
                process,
                local_workers,
                shape.to_vec(),
                config.checkpoint_interval,
            )
            .unwrap_or_else(|e| panic!("cannot start checkpoint writer in {dir}: {e}")),
        )
    } else {
        None
    };
    let setup = RecoverySetup {
        interval: config.checkpoint_interval,
        writer: writer.as_ref().map(CheckpointWriter::sender),
        restore,
    };
    (writer, Some(setup))
}

/// Installs a worker's [`RecoveryContext`] (built thread-locally from the
/// `Send` setup slice) before the dataflow is constructed, so operator
/// registration and input rewind both see it.
fn install_recovery<T: Timestamp>(
    worker: &mut Worker<T>,
    index: usize,
    setup: &Option<RecoverySetup>,
) {
    if let Some(setup) = setup {
        worker.set_recovery(Rc::new(RecoveryContext::new(
            index,
            setup.interval,
            setup.writer.clone(),
            setup.restore.clone(),
        )));
    }
}

/// Runs `build` on `config.workers` worker threads; each invocation builds
/// the (identical) dataflow and drives its worker. Returns each worker's
/// result, in worker-index order.
pub fn execute<T, R, F>(config: Config, build: F) -> Vec<R>
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    execute_inner(config, build).0
}

/// [`execute`]'s body, additionally handing back the shared fabric so
/// callers can snapshot telemetry after every worker has finished.
fn execute_inner<T, R, F>(config: Config, build: F) -> (Vec<R>, Arc<Fabric>)
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    let peers = config.workers.max(1);
    let fabric = Fabric::with_ring_capacity(peers, config.ring_capacity);
    let plane = trace_plane(&config, 0, 0, peers);
    if let Some(plane) = &plane {
        plane.attach_fabric(fabric.clone());
    }
    let (writer, recovery) = recovery_plumbing::<T>(&config, 0, peers, &[peers]);
    let build = Arc::new(build);
    let pin = config.pin_workers;
    let progress_flush = config.progress_flush;
    let send_batch = config.send_batch;

    let mut handles = Vec::with_capacity(peers);
    for index in 0..peers {
        let fabric = fabric.clone();
        let build = build.clone();
        let recovery = recovery.clone();
        let plane = plane.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("worker-{index}"))
                .spawn(move || {
                    if pin {
                        pin_to_core(index);
                    }
                    let mut worker = Worker::new(index, peers, fabric);
                    worker.set_progress_flush(progress_flush);
                    worker.set_send_batch(send_batch);
                    if let Some(plane) = &plane {
                        worker.set_tracer(plane.worker_tracer(index, index));
                    }
                    install_recovery(&mut worker, index, &recovery);
                    build(&mut worker)
                })
                .expect("spawn worker thread"),
        );
    }
    let results = handles
        .into_iter()
        .map(|h| h.join().expect("worker thread panicked"))
        .collect();
    if let Some(writer) = writer {
        writer.finish().expect("checkpoint writer failed");
    }
    if let Some(plane) = &plane {
        plane.finish().expect("trace writer failed");
    }
    (results, fabric)
}

/// Builds this process's [`TracePlane`](crate::observe::TracePlane) when
/// `config` asks for tracing or metrics, with per-process output paths
/// in multi-process runs.
fn trace_plane(
    config: &Config,
    process: usize,
    base_worker: usize,
    local_workers: usize,
) -> Option<Arc<crate::observe::TracePlane>> {
    if config.trace_path.is_none() && config.metrics_path.is_none() {
        return None;
    }
    let per = |p: &String| crate::observe::per_process_path(p, process, config.processes);
    Some(crate::observe::TracePlane::spawn(crate::observe::TraceConfig {
        trace_path: config.trace_path.as_ref().map(per),
        metrics_path: config.metrics_path.as_ref().map(per),
        process,
        base_worker,
        local_workers,
        print_summary: true,
    }))
}

/// Single-worker convenience wrapper: returns the sole worker's result.
pub fn execute_single<T, R, F>(build: F) -> R
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    execute(Config { workers: 1, ..Config::default() }, build)
        .pop()
        .expect("one worker")
}

// ---------------------------------------------------------------------------
// Cluster execution.
// ---------------------------------------------------------------------------

/// Handshake magic: "ttdnetv1" as little-endian bytes.
const HANDSHAKE_MAGIC: u64 = u64::from_le_bytes(*b"ttdnetv1");

/// Bumped whenever the wire format or handshake layout changes.
/// Version 5: WELCOME additionally carries process 0's trace/metrics
/// output paths (length-prefixed strings, empty = disabled), so one
/// process's `--trace`/`--metrics` flags observe the whole cluster.
/// Version 4 added process 0's parking mode and autotune flag (one byte
/// each), and a shared-memory rendezvous exchanging optional futex
/// wake-word paths alongside the ring paths; version 3 added the
/// transport byte so both sides pin the same per-link transport before
/// any frame crosses; version 2 added the per-process broadcast
/// progress frames (dedup fan-out) and the full per-process
/// worker-count shape.
const HANDSHAKE_VERSION: u32 = 5;

/// Per-link transport tags on the wire (the handshake's transport byte).
const LINK_TCP: u8 = 0;
const LINK_SHM: u8 = 1;
const LINK_THREADS: u8 = 2;

fn transport_name(tag: u8) -> &'static str {
    match tag {
        LINK_TCP => "tcp",
        LINK_SHM => "shm",
        LINK_THREADS => "tcp-threads",
        _ => "unknown",
    }
}

/// Parking-mode tags on the wire (the WELCOME's parking byte).
fn parking_tag(parking: Parking) -> u8 {
    match parking {
        Parking::Auto => 0,
        Parking::Doorbell => 1,
        Parking::Futex => 2,
    }
}

fn parking_from_tag(tag: u8) -> Result<Parking, NetError> {
    match tag {
        0 => Ok(Parking::Auto),
        1 => Ok(Parking::Doorbell),
        2 => Ok(Parking::Futex),
        other => Err(NetError::Protocol(format!("unknown parking tag {other}"))),
    }
}

/// Whether `address` (a `host:port`) names the local machine — the
/// condition under which `NetTransport::Auto` takes the shared-memory
/// path for a link.
fn is_loopback(address: &str) -> bool {
    let host = address.rsplit_once(':').map(|(h, _)| h).unwrap_or(address);
    let host = host.trim_start_matches('[').trim_end_matches(']');
    host == "localhost" || host == "::1" || host.starts_with("127.")
}

/// The transport tag both endpoints of the `a`↔`b` link must agree on,
/// derived deterministically from the (cluster-wide, identical) config so
/// connector and acceptor compute the same answer; the handshake byte
/// turns any config skew into a `Protocol` error instead of a hung or
/// corrupted stream.
fn link_transport(config: &Config, a: usize, b: usize) -> u8 {
    match config.net_transport {
        NetTransport::Tcp => LINK_TCP,
        NetTransport::Shm => LINK_SHM,
        NetTransport::TcpThreads => LINK_THREADS,
        NetTransport::Auto => {
            if is_loopback(&config.addresses[a]) && is_loopback(&config.addresses[b]) {
                LINK_SHM
            } else {
                LINK_TCP
            }
        }
    }
}

/// How long bootstrap keeps retrying a refused connection (peers may not
/// be listening yet; start order is free).
const CONNECT_RETRY_FOR: Duration = Duration::from_secs(30);

/// Reads and validates the shape vector trailing a handshake record: the
/// peer's per-process worker counts must equal `shape` exactly.
fn read_shape(stream: &mut TcpStream, shape: &[usize]) -> Result<(), NetError> {
    let mut buf = vec![0u8; 4 * shape.len()];
    stream.read_exact(&mut buf)?;
    for (p, expected) in shape.iter().enumerate() {
        let got =
            u32::from_le_bytes(buf[4 * p..4 * p + 4].try_into().expect("4 bytes")) as usize;
        if got != *expected {
            return Err(NetError::Protocol(format!(
                "cluster shape mismatch at process {p}: peer says {got} workers, \
                 local config says {expected}"
            )));
        }
    }
    Ok(())
}

/// Appends the shape vector (`u32` per process) to a handshake buffer.
fn push_shape(buf: &mut Vec<u8>, shape: &[usize]) {
    for workers in shape {
        buf.extend_from_slice(&(*workers as u32).to_le_bytes());
    }
}

/// Appends an optional string as `u32` length + bytes (`None` is a zero
/// length, indistinguishable from the empty string — both mean "off"
/// for the paths this carries).
fn push_lp_string(buf: &mut Vec<u8>, s: Option<&str>) {
    let s = s.unwrap_or("");
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

/// Reads a length-prefixed string written by [`push_lp_string`].
fn read_lp_string(stream: &mut TcpStream) -> Result<Option<String>, NetError> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > 4096 {
        return Err(NetError::Protocol(format!("absurd handshake string length {len}")));
    }
    if len == 0 {
        return Ok(None);
    }
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf)?;
    let s = String::from_utf8(buf)
        .map_err(|_| NetError::Protocol("handshake string is not utf-8".into()))?;
    Ok(Some(s))
}

/// `HELLO` (connector → acceptor): magic, version, sender, process count,
/// the proposed link transport, then the full per-process worker shape.
/// All little-endian.
fn write_hello(
    stream: &mut TcpStream,
    config: &Config,
    shape: &[usize],
    peer: usize,
) -> Result<(), NetError> {
    let mut buf = Vec::with_capacity(21 + 4 * shape.len());
    buf.extend_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    buf.extend_from_slice(&HANDSHAKE_VERSION.to_le_bytes());
    buf.extend_from_slice(&(config.process_index as u32).to_le_bytes());
    buf.extend_from_slice(&(config.processes as u32).to_le_bytes());
    buf.push(link_transport(config, config.process_index, peer));
    push_shape(&mut buf, shape);
    stream.write_all(&buf)?;
    stream.flush()?;
    Ok(())
}

/// Reads and validates a `HELLO`, returning the connecting process index.
fn read_hello(
    stream: &mut TcpStream,
    config: &Config,
    shape: &[usize],
) -> Result<usize, NetError> {
    let mut buf = [0u8; 21];
    stream.read_exact(&mut buf)?;
    let magic = u64::from_le_bytes(buf[0..8].try_into().expect("8 bytes"));
    let version = u32::from_le_bytes(buf[8..12].try_into().expect("4 bytes"));
    let process = u32::from_le_bytes(buf[12..16].try_into().expect("4 bytes")) as usize;
    let processes = u32::from_le_bytes(buf[16..20].try_into().expect("4 bytes")) as usize;
    let transport = buf[20];
    if magic != HANDSHAKE_MAGIC {
        return Err(NetError::Protocol("bad magic (not a ttd peer?)".into()));
    }
    if version != HANDSHAKE_VERSION {
        return Err(NetError::Protocol(format!(
            "wire version mismatch: peer {version}, local {HANDSHAKE_VERSION}"
        )));
    }
    if processes != config.processes {
        return Err(NetError::Protocol(format!(
            "cluster shape mismatch: peer says {processes} processes, local config says {}",
            config.processes
        )));
    }
    read_shape(stream, shape)?;
    if process >= processes {
        return Err(NetError::Protocol(format!("peer index {process} out of range")));
    }
    let expected = link_transport(config, config.process_index, process);
    if transport != expected {
        return Err(NetError::Protocol(format!(
            "net transport mismatch with process {process}: peer proposes {}, \
             local config selects {} (pass the same --net to every process)",
            transport_name(transport),
            transport_name(expected)
        )));
    }
    Ok(process)
}

/// `WELCOME` (acceptor → connector): echoes the cluster identity, carries
/// the acceptor's tuning (including the parking mode and autotune flag,
/// so one process's flags select the cluster's wake protocol and
/// governor), then the shape. The connector adopts the tuning only from
/// process 0, which makes process 0's flags authoritative for the whole
/// cluster (every process connects to 0 before spawning workers).
fn write_welcome(
    stream: &mut TcpStream,
    config: &Config,
    shape: &[usize],
    peer: usize,
) -> Result<(), NetError> {
    let mut buf = Vec::with_capacity(55 + 4 * shape.len());
    buf.extend_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    buf.extend_from_slice(&HANDSHAKE_VERSION.to_le_bytes());
    buf.extend_from_slice(&(config.process_index as u32).to_le_bytes());
    buf.extend_from_slice(&(config.processes as u32).to_le_bytes());
    buf.extend_from_slice(&(config.ring_capacity as u64).to_le_bytes());
    buf.extend_from_slice(&(config.progress_flush.as_nanos() as u64).to_le_bytes());
    buf.extend_from_slice(&(config.send_batch as u64).to_le_bytes());
    buf.push(link_transport(config, config.process_index, peer));
    buf.push(parking_tag(config.parking));
    buf.push(config.autotune as u8);
    push_lp_string(&mut buf, config.trace_path.as_deref());
    push_lp_string(&mut buf, config.metrics_path.as_deref());
    push_shape(&mut buf, shape);
    stream.write_all(&buf)?;
    stream.flush()?;
    Ok(())
}

/// Reads a `WELCOME`; if it came from process 0, adopts its tuning into
/// the local config (the "config propagation" half of the handshake).
fn read_welcome(
    stream: &mut TcpStream,
    config: &mut Config,
    shape: &[usize],
    peer: usize,
) -> Result<(), NetError> {
    let mut buf = [0u8; 47];
    stream.read_exact(&mut buf)?;
    let magic = u64::from_le_bytes(buf[0..8].try_into().expect("8 bytes"));
    let version = u32::from_le_bytes(buf[8..12].try_into().expect("4 bytes"));
    let process = u32::from_le_bytes(buf[12..16].try_into().expect("4 bytes")) as usize;
    if magic != HANDSHAKE_MAGIC || version != HANDSHAKE_VERSION {
        return Err(NetError::Protocol("bad welcome".into()));
    }
    if process != peer {
        return Err(NetError::Protocol(format!(
            "connected to {peer} but process {process} answered (address list skew?)"
        )));
    }
    // Every WELCOME carries the paths (framing), but only process 0's
    // are adopted — its flags observe the whole cluster.
    let trace_path = read_lp_string(stream)?;
    let metrics_path = read_lp_string(stream)?;
    if peer == 0 {
        config.ring_capacity =
            u64::from_le_bytes(buf[20..28].try_into().expect("8 bytes")) as usize;
        config.progress_flush = Duration::from_nanos(u64::from_le_bytes(
            buf[28..36].try_into().expect("8 bytes"),
        ));
        config.send_batch = u64::from_le_bytes(buf[36..44].try_into().expect("8 bytes")) as usize;
        config.parking = parking_from_tag(buf[45])?;
        config.autotune = buf[46] != 0;
        config.trace_path = trace_path;
        config.metrics_path = metrics_path;
    }
    let transport = buf[44];
    let expected = link_transport(config, config.process_index, peer);
    if transport != expected {
        return Err(NetError::Protocol(format!(
            "net transport mismatch with process {peer}: peer selects {}, \
             local config selects {} (pass the same --net to every process)",
            transport_name(transport),
            transport_name(expected)
        )));
    }
    read_shape(stream, shape)?;
    Ok(())
}

/// First connect-retry backoff step; doubles per attempt up to
/// [`CONNECT_RETRY_MAX_BACKOFF`].
const CONNECT_RETRY_BASE: Duration = Duration::from_millis(10);

/// Backoff ceiling: retries settle to one attempt per second, so a slow
/// peer costs at most a second of extra startup latency while a dead one
/// does not get hammered for the whole [`CONNECT_RETRY_FOR`] window.
const CONNECT_RETRY_MAX_BACKOFF: Duration = Duration::from_secs(1);

/// Connects to process `peer` at `address`, retrying with exponential
/// backoff (the peer may not be listening yet; start order is free) under
/// an overall [`CONNECT_RETRY_FOR`] deadline. A peer that never appears
/// fails the bootstrap with an error naming *which* process was
/// unreachable and the last OS error — the difference between "fix
/// process 2's host" and rechecking every address in the list.
fn connect_with_retry(peer: usize, address: &str) -> Result<TcpStream, NetError> {
    let deadline = Instant::now() + CONNECT_RETRY_FOR;
    let mut backoff = CONNECT_RETRY_BASE;
    loop {
        match TcpStream::connect(address) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(NetError::Protocol(format!(
                        "bootstrap: could not reach process {peer} at {address} \
                         within {CONNECT_RETRY_FOR:?}: {e}"
                    )));
                }
                // Never sleep past the deadline: the final attempt should
                // land at the deadline, not a full backoff beyond it.
                std::thread::sleep(backoff.min(deadline - now));
                backoff = (backoff * 2).min(CONNECT_RETRY_MAX_BACKOFF);
            }
        }
    }
}

/// Upgrades a handshaken bootstrap connection to a shared-memory link:
/// each side creates its outbound `/dev/shm` ring, the paths cross over
/// the socket, each side maps the peer's ring and acks, and the ring
/// files are unlinked (the mappings outlive the names). The socket
/// itself is retained as the link's parking doorbell. `wake_path` is
/// this process's futex wake-word segment, advertised to the peer iff
/// this process will park in futex mode (the peer then bumps the word
/// instead of ringing the doorbell).
fn shm_rendezvous(mut stream: TcpStream, wake_path: Option<&Path>) -> Result<NetLink, NetError> {
    let (path, tx) = create_ring(SHM_RING_BYTES)?;
    let exchanged = shm_exchange(&mut stream, &path, wake_path);
    // Unlink our ring in every outcome: after a successful exchange the
    // peer has mapped it (its ack says so), and a failed bootstrap must
    // not leak /dev/shm segments.
    let _ = std::fs::remove_file(&path);
    let (rx, peer_wake) = exchanged?;
    Ok(NetLink::Shm(ShmLink { tx, rx, doorbell: stream, peer_wake }))
}

/// The symmetric half of [`shm_rendezvous`]: sends our ring's capacity
/// and path plus our (optional, zero-length = none) wake-word path, maps
/// the peer's ring and wake word, and exchanges one-byte acks so neither
/// side unlinks a segment the other has not yet mapped.
fn shm_exchange(
    stream: &mut TcpStream,
    path: &Path,
    wake_path: Option<&Path>,
) -> Result<(ShmConsumer, Option<WakeWord>), NetError> {
    let path_str = path.to_str().expect("shm ring path is utf-8");
    let wake_str = wake_path.map(|p| p.to_str().expect("wake word path is utf-8"));
    let mut hdr = Vec::with_capacity(16 + path_str.len());
    hdr.extend_from_slice(&(SHM_RING_BYTES as u64).to_le_bytes());
    hdr.extend_from_slice(&(path_str.len() as u32).to_le_bytes());
    hdr.extend_from_slice(path_str.as_bytes());
    hdr.extend_from_slice(&(wake_str.map_or(0, str::len) as u32).to_le_bytes());
    if let Some(wake) = wake_str {
        hdr.extend_from_slice(wake.as_bytes());
    }
    stream.write_all(&hdr)?;
    stream.flush()?;

    let mut fixed = [0u8; 12];
    stream.read_exact(&mut fixed)?;
    let peer_cap = u64::from_le_bytes(fixed[0..8].try_into().expect("8 bytes")) as usize;
    let len = u32::from_le_bytes(fixed[8..12].try_into().expect("4 bytes")) as usize;
    if len > 4096 {
        return Err(NetError::Protocol(format!("absurd shm path length {len}")));
    }
    let mut peer_path = vec![0u8; len];
    stream.read_exact(&mut peer_path)?;
    let peer_path = String::from_utf8(peer_path)
        .map_err(|_| NetError::Protocol("shm ring path is not utf-8".into()))?;
    let rx = open_ring(Path::new(&peer_path), peer_cap)?;

    let mut wake_len = [0u8; 4];
    stream.read_exact(&mut wake_len)?;
    let wake_len = u32::from_le_bytes(wake_len) as usize;
    if wake_len > 4096 {
        return Err(NetError::Protocol(format!("absurd wake word path length {wake_len}")));
    }
    let peer_wake = if wake_len > 0 {
        let mut peer_wake_path = vec![0u8; wake_len];
        stream.read_exact(&mut peer_wake_path)?;
        let peer_wake_path = String::from_utf8(peer_wake_path)
            .map_err(|_| NetError::Protocol("wake word path is not utf-8".into()))?;
        Some(open_wake_word(Path::new(&peer_wake_path))?)
    } else {
        None
    };

    stream.write_all(&[1u8])?;
    stream.flush()?;
    let mut ack = [0u8; 1];
    stream.read_exact(&mut ack)?;
    Ok((rx, peer_wake))
}

/// Turns a handshaken bootstrap connection into the link the two sides
/// agreed on (the handshake's transport byte has already pinned the
/// agreement, so both run the matching arm).
fn finish_link(
    config: &Config,
    stream: TcpStream,
    peer: usize,
    wake_path: Option<&Path>,
) -> Result<NetLink, NetError> {
    match link_transport(config, config.process_index, peer) {
        LINK_SHM => shm_rendezvous(stream, wake_path),
        LINK_THREADS => {
            let (tx, rx) = tcp_pair(stream)?;
            Ok(NetLink::Threads(Box::new(tx), Box::new(rx)))
        }
        _ => Ok(NetLink::Tcp(stream)),
    }
}

/// Whether this process's reactor may park in a futex instead of a
/// descriptor sleep: the flag allows it, the target supports the
/// syscall, and EVERY remote link is shared memory — an fd-borne link
/// (TCP or thread-pair) needs the reactor asleep in its fd set, which a
/// futex bump cannot rouse. Called after process 0's WELCOME has been
/// adopted, so the whole cluster computes the same answer.
fn futex_eligible(config: &Config) -> bool {
    if config.parking == Parking::Doorbell || !futex_supported() {
        return false;
    }
    (0..config.processes)
        .filter(|p| *p != config.process_index)
        .all(|p| link_transport(config, config.process_index, p) == LINK_SHM)
}

/// Establishes the full mesh for `config` (whose cluster shape is
/// `shape`), returning one link per process (`None` at
/// `config.process_index`) plus this process's own futex wake word (when
/// it parks in futex mode; every shm peer has mapped the word and bumps
/// it), and adopting process 0's tuning into `config`.
fn bootstrap(
    config: &mut Config,
    shape: &[usize],
) -> Result<(Vec<Option<NetLink>>, Option<Arc<WakeWord>>), NetError> {
    let me = config.process_index;
    let processes = config.processes;
    if config.addresses.len() != processes {
        return Err(NetError::Protocol(format!(
            "need one address per process: got {} for {processes} processes",
            config.addresses.len()
        )));
    }
    let listener = TcpListener::bind(&config.addresses[me]).map_err(|e| {
        NetError::Protocol(format!("cannot listen on {}: {e}", config.addresses[me]))
    })?;

    let mut links: Vec<Option<NetLink>> =
        (0..processes).map(|_| None).collect();
    // Created lazily at the first link: for `me > 0` futex eligibility
    // depends on process 0's WELCOME (parking mode), which lands before
    // the first `finish_link`. `None` here still means "undecided".
    let mut wake: Option<(PathBuf, Arc<WakeWord>)> = None;
    let mut decided = false;
    let mut decide = |config: &Config,
                      wake: &mut Option<(PathBuf, Arc<WakeWord>)>|
     -> Result<(), NetError> {
        if !decided {
            decided = true;
            if futex_eligible(config) {
                let (path, word) = create_wake_word()?;
                *wake = Some((path, Arc::new(word)));
            }
        }
        Ok(())
    };

    // Connect to every lower-indexed process, in order — 0 first, so its
    // WELCOME configures this process before anything else happens.
    for peer in 0..me {
        let mut stream = connect_with_retry(peer, &config.addresses[peer])?;
        // Bound the reply read: a wedged peer (or an unrelated service on
        // the address) must fail the bootstrap, not hang it.
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        write_hello(&mut stream, config, shape, peer)?;
        read_welcome(&mut stream, config, shape, peer)?;
        let _ = stream.set_read_timeout(None);
        decide(config, &mut wake)?;
        let wake_path = wake.as_ref().map(|(p, _)| p.as_path());
        links[peer] = Some(finish_link(config, stream, peer, wake_path)?);
    }

    // Accept every higher-indexed process, identified by its HELLO.
    let mut expected: usize = processes - 1 - me;
    while expected > 0 {
        let (mut stream, _addr) = listener.accept()?;
        // Bound the handshake read so a silent stray connection cannot
        // wedge the accept loop.
        let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
        let peer = match read_hello(&mut stream, config, shape) {
            Ok(peer) => peer,
            // A stray or dying connection (port scanner, crashed peer
            // retrying) must not wedge the bootstrap: drop it and keep
            // accepting. Real misconfigurations surface as Protocol.
            Err(NetError::Io(_)) => continue,
            Err(e) => return Err(e),
        };
        let _ = stream.set_read_timeout(None);
        if peer <= me || links[peer].is_some() {
            return Err(NetError::Protocol(format!("unexpected connection from {peer}")));
        }
        write_welcome(&mut stream, config, shape, peer)?;
        decide(config, &mut wake)?;
        let wake_path = wake.as_ref().map(|(p, _)| p.as_path());
        links[peer] = Some(finish_link(config, stream, peer, wake_path)?);
        expected -= 1;
    }
    // Every peer that needed the wake word has mapped it: the name can
    // go (the mappings outlive it), and a crashed bootstrap must not
    // leak /dev/shm segments.
    let wake = wake.map(|(path, word)| {
        let _ = std::fs::remove_file(&path);
        word
    });
    Ok((links, wake))
}

/// Runs `build` on every worker this process hosts, as part of a
/// `config.processes`-process cluster (every process must call this with
/// the same cluster shape and its own `process_index`). The shape may be
/// heterogeneous: `config.cluster_shape` gives per-process worker counts
/// (empty = `config.workers` everywhere). Returns the *local* workers'
/// results, in global index order. With `processes <= 1` this is exactly
/// [`execute`].
pub fn execute_cluster<T, R, F>(config: Config, build: F) -> Result<Vec<R>, NetError>
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    execute_cluster_telemetry(config, build).map(|(results, _telemetry)| results)
}

/// [`execute_cluster`] plus the local workers' fabric telemetry, in
/// global index order, snapshotted AFTER the net fabric's shutdown — by
/// then every peer's stream has reached end-of-stream and every inbound
/// frame has been demuxed (broadcast frames fanned out), so cross-process
/// counter relations (e.g. the broadcast-dedup frame/delivery ratio the
/// cluster tests assert) are exact rather than racing in-flight frames.
pub fn execute_cluster_telemetry<T, R, F>(
    config: Config,
    build: F,
) -> Result<(Vec<R>, Vec<crate::worker::allocator::WorkerTelemetry>), NetError>
where
    T: Timestamp,
    R: Send + 'static,
    F: Fn(&mut Worker<T>) -> R + Send + Sync + 'static,
{
    if config.processes <= 1 {
        let (results, fabric) = execute_inner(config, build);
        let telemetry = fabric.telemetry_all();
        return Ok((results, telemetry));
    }
    let mut config = config;
    let shape = config.shape();
    if shape.len() != config.processes {
        return Err(NetError::Protocol(format!(
            "cluster_shape names {} processes but config.processes is {}",
            shape.len(),
            config.processes
        )));
    }
    config.workers = shape[config.process_index];
    let (links, wake) = bootstrap(&mut config, &shape)?;

    let process = config.process_index;
    let local_workers = shape[process];
    // The governor (opt-in, propagated from process 0) shares its state
    // with workers: each worker re-reads the progress-flush cadence when
    // the generation stamp moves.
    let tune = if config.autotune {
        Some(Arc::new(TuneShared::new(config.progress_flush, config.send_batch)))
    } else {
        None
    };
    // The plane must exist before the net fabric: the reactor's tracer
    // rides in the fabric's options. The worker fabric (the telemetry
    // source) is late-attached below once built.
    let plane = trace_plane(&config, process, shape[..process].iter().sum(), local_workers);
    let options = FabricOptions {
        backend: config.reactor_backend.resolve(),
        wake,
        tune: tune.clone(),
        trace: plane.as_ref().map(|p| p.reactor_tracer()),
    };
    let net = NetFabric::new_with(process, shape.clone(), links, config.ring_capacity, options);
    let fabric = Fabric::cluster(&shape, process, config.ring_capacity, net.clone());
    if let Some(plane) = &plane {
        plane.attach_fabric(fabric.clone());
    }
    let peers = fabric.peers();
    let base = fabric.local_base();
    let (writer, recovery) = recovery_plumbing::<T>(&config, process, local_workers, &shape);
    let build = Arc::new(build);
    let pin = config.pin_workers;
    let progress_flush = config.progress_flush;
    let send_batch = config.send_batch;

    let mut handles = Vec::with_capacity(local_workers);
    for local in 0..local_workers {
        let fabric = fabric.clone();
        let build = build.clone();
        let tune = tune.clone();
        let recovery = recovery.clone();
        let plane = plane.clone();
        let index = base + local;
        handles.push(
            std::thread::Builder::new()
                .name(format!("worker-{index}"))
                .spawn(move || {
                    if pin {
                        pin_to_core(local);
                    }
                    let mut worker = Worker::new(index, peers, fabric);
                    worker.set_progress_flush(progress_flush);
                    worker.set_send_batch(send_batch);
                    worker.set_tune(tune);
                    if let Some(plane) = &plane {
                        worker.set_tracer(plane.worker_tracer(local, index));
                    }
                    install_recovery(&mut worker, index, &recovery);
                    build(&mut worker)
                })
                .expect("spawn worker thread"),
        );
    }
    let results: Vec<R> = handles
        .into_iter()
        .map(|h| h.join().expect("worker thread panicked"))
        .collect();
    // Every worker's final captures are queued before its thread exits, so
    // joining the writer here makes the run's last checkpoint durable.
    if let Some(writer) = writer {
        writer.finish().expect("checkpoint writer failed");
    }
    // Every local worker has completed (and flushed, via `Worker::drop`):
    // drain the outbound queues to the wire and close the links cleanly.
    net.shutdown();
    // The reactor (the last trace producer) is quiescent only after
    // shutdown, so the plane's final drain comes after it.
    if let Some(plane) = &plane {
        plane.finish().expect("trace writer failed");
    }
    let telemetry = (base..base + local_workers).map(|w| fabric.telemetry(w)).collect();
    Ok((results, telemetry))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parking_tags_round_trip() {
        for parking in [Parking::Auto, Parking::Doorbell, Parking::Futex] {
            assert_eq!(parking_from_tag(parking_tag(parking)).unwrap(), parking);
        }
        assert!(parking_from_tag(3).is_err(), "unknown parking tags must be rejected");
    }
}
