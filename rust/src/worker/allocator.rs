//! The cross-worker communication fabric.
//!
//! Workers build identical dataflow graphs in the same order, so channel
//! identifiers agree without coordination. Each directed channel instance
//! `(channel, from, to)` is one bounded SPSC FIFO ring ([`super::ring`]) —
//! the same primitive under the progress plane's mailboxes and the data
//! plane's exchange channels, so both planes share one transport
//! abstraction (and a future serializing allocator only has to provide
//! FIFO byte streams to extend either across processes). Whichever side
//! asks first creates the ring pair and parks the counterpart half for the
//! other worker to claim.
//!
//! Both pending maps live under ONE mutex (construction-time only — no
//! lock is ever taken on the message path): claiming involves looking in
//! one map and inserting into the other, and taking two locks in
//! caller-dependent order deadlocks (worker A resolving a sender while
//! worker B resolves the matching receiver).
//!
//! Beyond point-to-point channels the fabric provides:
//!
//! * a **typed broadcast family** ([`Fabric::broadcast_senders`] /
//!   [`Fabric::broadcast_receivers`]): the per-peer SPSC ring fan used
//!   by the decentralized progress plane
//!   ([`crate::progress::exchange::Progcaster`]) — one FIFO ring per
//!   ordered worker pair, `None` at the self index;
//! * **park/unpark handles** ([`Fabric::register_worker_thread`] /
//!   [`Fabric::unpark_peers`]): idle workers park their thread instead of
//!   busy-spinning, and any worker that pushes progress batches or data
//!   messages into the fabric wakes its peers. The `std::thread` unpark
//!   token makes this race-free: an unpark delivered between a worker's
//!   "nothing to do" check and its park causes the park to return
//!   immediately, so no wakeup is lost;
//! * **per-worker telemetry** ([`Fabric::telemetry`]): park/unpark and
//!   ring-full stall counters, surfaced through the harness reports so
//!   scheduler pathologies (wakeup storms, backpressure stalls) are
//!   visible in benchmark output.

use super::ring::{self, RingReceiver, RingSender};
use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::thread::Thread;

/// Default slots per fabric ring. Progress batches coalesce and data
/// batches carry up to `SEND_BATCH` records each, so a modest ring depth
/// covers bursts; a full ring is not an error — senders keep messages
/// staged and retry after the peer drains (counted as a stall in
/// [`WorkerTelemetry`]). Configurable per run through
/// `Config::ring_capacity` (swept by `micro_exchange --sweep-ring`, which
/// uses the stall counters to show where a ring is too shallow).
pub const RING_CAPACITY: usize = 256;

type Key = (usize, usize, usize); // (channel, from, to)

#[derive(Default)]
struct Pending {
    senders: HashMap<Key, Box<dyn Any + Send>>,
    receivers: HashMap<Key, Box<dyn Any + Send>>,
}

/// Shared per-worker event counters, updated lock-free from the worker's
/// own thread (parks, stalls) and its peers (unparks).
#[derive(Default)]
pub struct WorkerStats {
    parks: AtomicU64,
    unparks: AtomicU64,
    ring_full: AtomicU64,
}

impl WorkerStats {
    /// Records that the owning worker parked its thread.
    #[inline]
    pub fn note_park(&self) {
        self.parks.fetch_add(1, Ordering::Relaxed);
    }

    /// Records that a peer unparked the owning worker.
    #[inline]
    pub fn note_unpark(&self) {
        self.unparks.fetch_add(1, Ordering::Relaxed);
    }

    /// Records a push rejected by a full ring (backpressure stall).
    #[inline]
    pub fn note_ring_full(&self) {
        self.ring_full.fetch_add(1, Ordering::Relaxed);
    }
}

/// A point-in-time snapshot of one worker's fabric counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerTelemetry {
    /// The worker's index.
    pub worker: usize,
    /// Times the worker parked its thread for lack of work.
    pub parks: u64,
    /// Times peers unparked this worker's thread.
    pub unparks: u64,
    /// Pushes (progress or data) rejected by a full ring and retried.
    pub ring_full_stalls: u64,
}

/// The shared endpoint registry.
pub struct Fabric {
    peers: usize,
    /// Slots per SPSC ring handed out by this fabric (both planes).
    ring_capacity: usize,
    pending: Mutex<Pending>,
    /// Per-worker thread handles for park/unpark wakeups. Write-once per
    /// slot (each worker registers from its own thread, before any flush
    /// traffic), so wakeups read them lock-free — no shared lock on the
    /// flush hot path.
    threads: Vec<OnceLock<Thread>>,
    /// Per-worker telemetry counters.
    stats: Vec<std::sync::Arc<WorkerStats>>,
}

impl Fabric {
    /// A fabric for `peers` workers with the default ring depth
    /// ([`RING_CAPACITY`]).
    pub fn new(peers: usize) -> std::sync::Arc<Self> {
        Self::with_ring_capacity(peers, RING_CAPACITY)
    }

    /// A fabric whose rings hold at least `ring_capacity` messages each
    /// (rounded up to a power of two by the ring itself; minimum 2). Wired
    /// to `Config::ring_capacity` by the executor.
    pub fn with_ring_capacity(peers: usize, ring_capacity: usize) -> std::sync::Arc<Self> {
        std::sync::Arc::new(Fabric {
            peers,
            ring_capacity: ring_capacity.max(2),
            pending: Mutex::new(Pending::default()),
            threads: (0..peers).map(|_| OnceLock::new()).collect(),
            stats: (0..peers).map(|_| std::sync::Arc::new(WorkerStats::default())).collect(),
        })
    }

    /// Number of workers sharing this fabric.
    pub fn peers(&self) -> usize {
        self.peers
    }

    /// Slots per ring this fabric hands out.
    pub fn ring_capacity(&self) -> usize {
        self.ring_capacity
    }

    /// A shared handle on worker `index`'s counters (cloned into channel
    /// send sides and progcasters so stalls are recorded without reaching
    /// back into the fabric).
    pub fn stats(&self, index: usize) -> std::sync::Arc<WorkerStats> {
        self.stats[index].clone()
    }

    /// A snapshot of worker `index`'s counters.
    pub fn telemetry(&self, index: usize) -> WorkerTelemetry {
        let stats = &self.stats[index];
        WorkerTelemetry {
            worker: index,
            parks: stats.parks.load(Ordering::Relaxed),
            unparks: stats.unparks.load(Ordering::Relaxed),
            ring_full_stalls: stats.ring_full.load(Ordering::Relaxed),
        }
    }

    /// Snapshots of every worker's counters, in index order.
    pub fn telemetry_all(&self) -> Vec<WorkerTelemetry> {
        (0..self.peers).map(|w| self.telemetry(w)).collect()
    }

    /// Registers the *calling* thread as worker `index`'s thread, making it
    /// a wakeup target for [`Fabric::unpark_peers`]. Called by the worker
    /// during construction (workers are built on their own threads); only
    /// the first registration per slot takes effect.
    pub fn register_worker_thread(&self, index: usize) {
        let _ = self.threads[index].set(std::thread::current());
    }

    /// Unparks every registered worker thread except `except` (the caller).
    ///
    /// Called after pushing progress batches or releasing data messages
    /// into the fabric, so parked peers observe them promptly. Unparking a
    /// running (or finished) thread is harmless; a not-yet-registered
    /// worker cannot have parked, so skipping its empty slot loses nothing.
    pub fn unpark_peers(&self, except: usize) {
        for (index, slot) in self.threads.iter().enumerate() {
            if index == except {
                continue;
            }
            if let Some(thread) = slot.get() {
                self.stats[index].note_unpark();
                thread.unpark();
            }
        }
    }

    /// Claims the send halves of channel `chan` from `from` to every other
    /// worker, in peer order (`None` at `from`): the fan-out half of a
    /// broadcast family. Each `(chan, from, to)` pair is an SPSC FIFO ring.
    pub fn broadcast_senders<M: Send + 'static>(
        &self,
        chan: usize,
        from: usize,
    ) -> Vec<Option<RingSender<M>>> {
        (0..self.peers)
            .map(|to| if to == from { None } else { Some(self.sender(chan, from, to)) })
            .collect()
    }

    /// Claims the receive halves of channel `chan` from every other worker
    /// to `to`, in peer order (`None` at `to`): the fan-in half of a
    /// broadcast family.
    pub fn broadcast_receivers<M: Send + 'static>(
        &self,
        chan: usize,
        to: usize,
    ) -> Vec<Option<RingReceiver<M>>> {
        (0..self.peers)
            .map(|from| if from == to { None } else { Some(self.receiver(chan, from, to)) })
            .collect()
    }

    /// Claims the send half of `(channel, from, to)`. Called by worker
    /// `from` exactly once per key.
    pub fn sender<M: Send + 'static>(&self, chan: usize, from: usize, to: usize) -> RingSender<M> {
        let key = (chan, from, to);
        let mut pending = self.pending.lock().unwrap();
        if let Some(tx) = pending.senders.remove(&key) {
            *tx.downcast::<RingSender<M>>().expect("channel type mismatch")
        } else {
            let (tx, rx) = ring::channel::<M>(self.ring_capacity);
            pending.receivers.insert(key, Box::new(rx));
            tx
        }
    }

    /// Claims the receive half of `(channel, from, to)`. Called by worker
    /// `to` exactly once per key.
    pub fn receiver<M: Send + 'static>(
        &self,
        chan: usize,
        from: usize,
        to: usize,
    ) -> RingReceiver<M> {
        let key = (chan, from, to);
        let mut pending = self.pending.lock().unwrap();
        if let Some(rx) = pending.receivers.remove(&key) {
            *rx.downcast::<RingReceiver<M>>().expect("channel type mismatch")
        } else {
            let (tx, rx) = ring::channel::<M>(self.ring_capacity);
            pending.senders.insert(key, Box::new(tx));
            rx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sender_first_then_receiver() {
        let fabric = Fabric::new(2);
        let mut tx = fabric.sender::<u32>(0, 0, 1);
        let mut rx = fabric.receiver::<u32>(0, 0, 1);
        tx.send(42).unwrap();
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn receiver_first_then_sender() {
        let fabric = Fabric::new(2);
        let mut rx = fabric.receiver::<u32>(3, 1, 0);
        let mut tx = fabric.sender::<u32>(3, 1, 0);
        tx.send(7).unwrap();
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn distinct_keys_distinct_channels() {
        let fabric = Fabric::new(2);
        let mut tx_a = fabric.sender::<u32>(0, 0, 1);
        let mut tx_b = fabric.sender::<u32>(1, 0, 1);
        let mut rx_a = fabric.receiver::<u32>(0, 0, 1);
        let mut rx_b = fabric.receiver::<u32>(1, 0, 1);
        tx_a.send(1).unwrap();
        tx_b.send(2).unwrap();
        assert_eq!(rx_a.recv().unwrap(), 1);
        assert_eq!(rx_b.recv().unwrap(), 2);
    }

    #[test]
    fn cross_thread_claiming() {
        let fabric = Fabric::new(2);
        let f2 = fabric.clone();
        let handle = std::thread::spawn(move || {
            let mut rx = f2.receiver::<String>(9, 0, 1);
            rx.recv().unwrap()
        });
        let mut tx = fabric.sender::<String>(9, 0, 1);
        tx.send("hello".to_string()).unwrap();
        assert_eq!(handle.join().unwrap(), "hello");
    }

    /// Regression: concurrent sender/receiver resolution across many keys
    /// must not deadlock (the two pending maps once lived under separate
    /// locks, acquired in opposite orders by the two claim paths).
    #[test]
    fn concurrent_claims_do_not_deadlock() {
        for _ in 0..50 {
            let fabric = Fabric::new(2);
            let f2 = fabric.clone();
            let a = std::thread::spawn(move || {
                for chan in 0..64 {
                    let _tx = f2.sender::<u64>(chan, 0, 1);
                    let _rx = f2.receiver::<u64>(chan, 1, 0);
                }
            });
            for chan in 0..64 {
                let _rx = fabric.receiver::<u64>(chan, 0, 1);
                let _tx = fabric.sender::<u64>(chan, 1, 0);
            }
            a.join().unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "type mismatch")]
    fn type_mismatch_panics() {
        let fabric = Fabric::new(2);
        let _tx = fabric.sender::<u32>(0, 0, 1);
        let _rx = fabric.receiver::<String>(0, 0, 1);
    }

    #[test]
    fn broadcast_family_matches_pairwise_endpoints() {
        let fabric = Fabric::new(3);
        let mut senders0 = fabric.broadcast_senders::<u64>(9, 0);
        assert_eq!(senders0.len(), 3);
        assert!(senders0[0].is_none(), "no self channel");
        let mut rx1 = fabric.broadcast_receivers::<u64>(9, 1);
        let mut rx2 = fabric.broadcast_receivers::<u64>(9, 2);
        senders0[1].as_mut().unwrap().send(11).unwrap();
        senders0[2].as_mut().unwrap().send(22).unwrap();
        assert_eq!(rx1[0].as_mut().unwrap().recv().unwrap(), 11);
        assert_eq!(rx2[0].as_mut().unwrap().recv().unwrap(), 22);
        assert!(rx1[1].is_none() && rx2[2].is_none());
    }

    #[test]
    fn unpark_wakes_a_parked_registered_worker() {
        let fabric = Fabric::new(2);
        let f2 = fabric.clone();
        let worker = std::thread::spawn(move || {
            f2.register_worker_thread(1);
            // Park for up to 5s; the unpark below must cut this short (or
            // land first, making park return immediately via the token).
            let start = std::time::Instant::now();
            std::thread::park_timeout(std::time::Duration::from_secs(5));
            start.elapsed()
        });
        // Give the worker a moment to register and park, then wake it.
        std::thread::sleep(std::time::Duration::from_millis(50));
        fabric.unpark_peers(0);
        let parked_for = worker.join().unwrap();
        assert!(
            parked_for < std::time::Duration::from_secs(4),
            "worker should have been unparked early, parked {parked_for:?}"
        );
        assert_eq!(fabric.telemetry(1).unparks, 1);
    }

    #[test]
    fn unpark_peers_skips_caller_and_unregistered_slots() {
        let fabric = Fabric::new(4);
        fabric.register_worker_thread(2);
        // Workers 0,1,3 never registered; this must not panic and must not
        // unpark the caller's own slot.
        fabric.unpark_peers(2);
        fabric.unpark_peers(0);
        assert_eq!(fabric.telemetry(2).unparks, 1);
        assert_eq!(fabric.telemetry(0).unparks, 0);
    }

    #[test]
    fn custom_ring_capacity_reaches_both_endpoints() {
        let fabric = Fabric::with_ring_capacity(2, 16);
        assert_eq!(fabric.ring_capacity(), 16);
        let tx = fabric.sender::<u32>(0, 0, 1);
        assert_eq!(tx.capacity(), 16);
        // The counterpart half parked by the sender claim has the same
        // depth (one ring, two endpoints).
        let _rx = fabric.receiver::<u32>(0, 0, 1);
        // Degenerate capacities clamp to the ring minimum instead of
        // panicking.
        let tiny = Fabric::with_ring_capacity(2, 0);
        assert_eq!(tiny.sender::<u32>(0, 0, 1).capacity(), 2);
    }

    #[test]
    fn telemetry_counters_accumulate() {
        let fabric = Fabric::new(2);
        let stats = fabric.stats(1);
        stats.note_park();
        stats.note_park();
        stats.note_ring_full();
        let t = fabric.telemetry(1);
        assert_eq!((t.parks, t.ring_full_stalls), (2, 1));
        assert_eq!(fabric.telemetry_all().len(), 2);
    }
}
