//! The cross-worker communication fabric — now spanning processes.
//!
//! Workers build identical dataflow graphs in the same order, so channel
//! identifiers agree without coordination. Each directed channel instance
//! `(channel, from, to)` between two workers **in the same process** is
//! one bounded SPSC FIFO ring ([`super::ring`]) — the same primitive under
//! the progress plane's mailboxes and the data plane's exchange channels.
//! Whichever side asks first creates the ring pair and parks the
//! counterpart half for the other worker to claim.
//!
//! In a cluster ([`Fabric::cluster`], reached through
//! `execute::execute_cluster`), worker indices are global and assigned in
//! contiguous per-process blocks. The *same* claim calls route a channel
//! endpoint either onto an intra-process ring or through the wire codec
//! onto a [`crate::net::NetFabric`] endpoint, depending only on where the
//! counterpart worker lives: [`Fabric::channel_sender`] /
//! [`Fabric::channel_receiver`] return [`FabricSender`] /
//! [`FabricReceiver`] enums whose net variants mirror the ring contract
//! exactly (`Full` is backpressure, `Disconnected` means the peer is
//! gone), so the staging / spill / produce-before-data-release machinery
//! is oblivious to the transport. The raw ring claims
//! ([`Fabric::sender`] / [`Fabric::receiver`]) remain available for
//! process-local plumbing and assert locality.
//!
//! Both pending maps live under ONE mutex (construction-time only — no
//! lock is ever taken on the message path): claiming involves looking in
//! one map and inserting into the other, and taking two locks in
//! caller-dependent order deadlocks (worker A resolving a sender while
//! worker B resolves the matching receiver).
//!
//! Beyond point-to-point channels the fabric provides:
//!
//! * the **progress plane's deduplicated broadcast routing**
//!   ([`Fabric::local_broadcast_senders`] +
//!   [`Fabric::progress_net_senders`] / [`Fabric::progress_receivers`]):
//!   same-process peers keep their per-pair SPSC ring mailboxes exactly
//!   as before, but each REMOTE process is reached by ONE per-process
//!   [`NetBroadcastSender`] — a flush ships one
//!   `ProgressBroadcast` frame per remote process carrying the
//!   destination-worker set, and the destination fabric fans the decoded
//!   batch out locally (`NetFabric::register_broadcast`), cutting
//!   cross-process progress bandwidth from `p·k` frames to `p`;
//! * **park/unpark handles** ([`Fabric::register_worker_thread`] /
//!   [`Fabric::unpark_peers`]): idle workers park their thread instead of
//!   busy-spinning, and any worker that pushes progress batches or data
//!   messages into the fabric wakes its peers. The `std::thread` unpark
//!   token makes this race-free: an unpark delivered between a worker's
//!   "nothing to do" check and its park causes the park to return
//!   immediately, so no wakeup is lost;
//! * **per-worker telemetry** ([`Fabric::telemetry`]): park/unpark and
//!   ring-full stall counters — plus, in a cluster, the net-plane counters
//!   (frames/bytes sent and received, send-queue stalls) — surfaced
//!   through the harness reports so scheduler pathologies (wakeup storms,
//!   backpressure stalls) are visible in benchmark output, grouped by
//!   process.

use super::ring::{self, RingReceiver, RingSendError, RingSender};
use crate::net::codec::{ProgressBroadcast, ProgressUpdates, Wire};
use crate::net::fabric::{ClusterShape, NetBroadcastSender, NetFabric, NetReceiver, NetSender};
use crate::progress::timestamp::Timestamp;
use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::TryRecvError;
use std::sync::{Mutex, OnceLock};
use std::thread::Thread;

/// Default slots per fabric ring. Progress batches coalesce and data
/// batches carry up to `SEND_BATCH` records each, so a modest ring depth
/// covers bursts; a full ring is not an error — senders keep messages
/// staged and retry after the peer drains (counted as a stall in
/// [`WorkerTelemetry`]). Configurable per run through
/// `Config::ring_capacity` (swept by `micro_exchange --sweep-ring`, which
/// uses the stall counters to show where a ring is too shallow).
pub const RING_CAPACITY: usize = 256;

type Key = (usize, usize, usize); // (channel, from, to)

#[derive(Default)]
struct Pending {
    senders: HashMap<Key, Box<dyn Any + Send>>,
    receivers: HashMap<Key, Box<dyn Any + Send>>,
}

/// Shared per-worker event counters, updated lock-free from the worker's
/// own thread (parks, stalls) and its peers (unparks).
#[derive(Default)]
pub struct WorkerStats {
    parks: AtomicU64,
    unparks: AtomicU64,
    ring_full: AtomicU64,
}

impl WorkerStats {
    /// Records that the owning worker parked its thread.
    #[inline]
    pub fn note_park(&self) {
        self.parks.fetch_add(1, Ordering::Relaxed);
    }

    /// Records that a peer unparked the owning worker.
    #[inline]
    pub fn note_unpark(&self) {
        self.unparks.fetch_add(1, Ordering::Relaxed);
    }

    /// Records a push rejected by a full ring (backpressure stall).
    #[inline]
    pub fn note_ring_full(&self) {
        self.ring_full.fetch_add(1, Ordering::Relaxed);
    }
}

/// A point-in-time snapshot of one worker's fabric counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerTelemetry {
    /// The worker's (global) index.
    pub worker: usize,
    /// The process the worker belongs to (0 in single-process runs).
    pub process: usize,
    /// Times the worker parked its thread for lack of work.
    pub parks: u64,
    /// Times peers unparked this worker's thread.
    pub unparks: u64,
    /// Pushes (progress or data) rejected by a full ring and retried.
    pub ring_full_stalls: u64,
    /// Net-plane counters (all zero in single-process runs).
    pub net: crate::net::NetTelemetry,
}

/// One channel endpoint's send half: an intra-process SPSC ring or a
/// serializing net endpoint, claimed transparently by
/// [`Fabric::channel_sender`]. Both variants share the non-blocking
/// `Full` / `Disconnected` contract.
pub enum FabricSender<M: Send + 'static> {
    /// Same-process destination: a lock-free SPSC ring.
    Ring(RingSender<M>),
    /// Remote destination: encode through the wire codec.
    Net(NetSender<M>),
}

impl<M: Wire + Send + 'static> FabricSender<M> {
    /// Pushes `m`, or hands it back if the endpoint is full (backpressure;
    /// retry after the counterpart drains) or the peer is gone.
    #[inline]
    pub fn send(&mut self, m: M) -> Result<(), RingSendError<M>> {
        match self {
            FabricSender::Ring(tx) => tx.send(m),
            FabricSender::Net(tx) => tx.send(m),
        }
    }

    /// Messages the endpoint admits before reporting `Full`.
    pub fn capacity(&self) -> usize {
        match self {
            FabricSender::Ring(tx) => tx.capacity(),
            FabricSender::Net(tx) => tx.capacity(),
        }
    }

    /// True iff this endpoint crosses a process boundary.
    pub fn is_net(&self) -> bool {
        matches!(self, FabricSender::Net(_))
    }
}

/// One channel endpoint's receive half (counterpart of [`FabricSender`]).
pub enum FabricReceiver<M: Send + 'static> {
    /// Same-process source: a lock-free SPSC ring.
    Ring(RingReceiver<M>),
    /// Remote source: decode through the wire codec.
    Net(NetReceiver<M>),
}

impl<M: Wire + Send + 'static> FabricReceiver<M> {
    /// Pops the next message: `Empty` while the endpoint is idle,
    /// `Disconnected` once it is drained and the sender is gone.
    #[inline]
    pub fn try_recv(&mut self) -> Result<M, TryRecvError> {
        match self {
            FabricReceiver::Ring(rx) => rx.try_recv(),
            FabricReceiver::Net(rx) => rx.try_recv(),
        }
    }

    /// Blocking receive by spinning with yields — tests and shutdown paths
    /// only (workers park instead).
    pub fn recv(&mut self) -> Result<M, TryRecvError> {
        loop {
            match self.try_recv() {
                Ok(m) => return Ok(m),
                Err(TryRecvError::Disconnected) => return Err(TryRecvError::Disconnected),
                Err(TryRecvError::Empty) => std::thread::yield_now(),
            }
        }
    }
}

/// The shared endpoint registry.
pub struct Fabric {
    /// Total workers across every process.
    peers: usize,
    /// This process's index (0 in single-process runs).
    process: usize,
    /// Total processes (1 in single-process runs).
    processes: usize,
    /// The cluster's worker layout (contiguous per-process index blocks,
    /// possibly of unequal size) — the same [`ClusterShape`] arithmetic
    /// the net fabric uses.
    shape: ClusterShape,
    /// Slots per SPSC ring handed out by this fabric (both planes).
    ring_capacity: usize,
    pending: Mutex<Pending>,
    /// Per-worker thread handles for park/unpark wakeups (only local
    /// workers' slots are ever registered). Write-once per slot (each
    /// worker registers from its own thread, before any flush traffic),
    /// so wakeups read them lock-free — no shared lock on the flush hot
    /// path.
    threads: Vec<OnceLock<Thread>>,
    /// Per-worker telemetry counters (only local workers' entries move).
    stats: Vec<std::sync::Arc<WorkerStats>>,
    /// The cross-process side; `None` in single-process runs.
    net: Option<std::sync::Arc<NetFabric>>,
}

impl Fabric {
    /// A single-process fabric for `peers` workers with the default ring
    /// depth ([`RING_CAPACITY`]).
    pub fn new(peers: usize) -> std::sync::Arc<Self> {
        Self::with_ring_capacity(peers, RING_CAPACITY)
    }

    /// A single-process fabric whose rings hold at least `ring_capacity`
    /// messages each (rounded up to a power of two by the ring itself;
    /// minimum 2). Wired to `Config::ring_capacity` by the executor.
    pub fn with_ring_capacity(peers: usize, ring_capacity: usize) -> std::sync::Arc<Self> {
        std::sync::Arc::new(Fabric {
            peers,
            process: 0,
            processes: 1,
            shape: ClusterShape::new(&[peers.max(1)]),
            ring_capacity: ring_capacity.max(2),
            pending: Mutex::new(Pending::default()),
            threads: (0..peers).map(|_| OnceLock::new()).collect(),
            stats: (0..peers).map(|_| std::sync::Arc::new(WorkerStats::default())).collect(),
            net: None,
        })
    }

    /// A cluster fabric: process `p` hosts `shape[p]` workers (unequal
    /// counts are first-class), in contiguous global index blocks; this
    /// process is `process`, and channels to the rest route through `net`
    /// (which must have been built with the same shape).
    pub fn cluster(
        shape: &[usize],
        process: usize,
        ring_capacity: usize,
        net: std::sync::Arc<NetFabric>,
    ) -> std::sync::Arc<Self> {
        let shape = ClusterShape::new(shape);
        let processes = shape.processes();
        assert!(process < processes, "process index out of range");
        let peers = shape.peers();
        std::sync::Arc::new(Fabric {
            peers,
            process,
            processes,
            shape,
            ring_capacity: ring_capacity.max(2),
            pending: Mutex::new(Pending::default()),
            threads: (0..peers).map(|_| OnceLock::new()).collect(),
            stats: (0..peers).map(|_| std::sync::Arc::new(WorkerStats::default())).collect(),
            net: Some(net),
        })
    }

    /// Number of workers sharing this fabric, across every process.
    pub fn peers(&self) -> usize {
        self.peers
    }

    /// This process's index.
    pub fn process(&self) -> usize {
        self.process
    }

    /// Total processes in the cluster (1 outside a cluster).
    pub fn processes(&self) -> usize {
        self.processes
    }

    /// The process hosting a given global worker index (contiguous blocks
    /// of possibly unequal size).
    #[inline]
    pub fn process_of(&self, worker: usize) -> usize {
        self.shape.process_of(worker)
    }

    /// True iff `worker` runs in this process.
    #[inline]
    pub fn is_local(&self, worker: usize) -> bool {
        self.local_base() <= worker
            && worker < self.local_base() + self.shape.workers(self.process)
    }

    /// The global index of this process's first worker.
    #[inline]
    pub fn local_base(&self) -> usize {
        self.shape.base(self.process)
    }

    /// The cross-process fabric, if this is a cluster.
    pub fn net(&self) -> Option<&std::sync::Arc<NetFabric>> {
        self.net.as_ref()
    }

    /// Peer processes observed to die abruptly (stream end without the
    /// orderly goodbye), in index order. Always empty for a single
    /// process. Workers poll this to quiesce instead of waiting forever
    /// on progress updates a dead peer will never send.
    pub fn lost_peers(&self) -> Vec<usize> {
        self.net.as_ref().map(|n| n.lost_peers()).unwrap_or_default()
    }

    /// Slots per ring this fabric hands out.
    pub fn ring_capacity(&self) -> usize {
        self.ring_capacity
    }

    /// A shared handle on worker `index`'s counters (cloned into channel
    /// send sides and progcasters so stalls are recorded without reaching
    /// back into the fabric).
    pub fn stats(&self, index: usize) -> std::sync::Arc<WorkerStats> {
        self.stats[index].clone()
    }

    /// A snapshot of worker `index`'s counters (net counters are filled in
    /// for local workers of a cluster, zero otherwise).
    pub fn telemetry(&self, index: usize) -> WorkerTelemetry {
        let stats = &self.stats[index];
        let net = match (&self.net, self.is_local(index)) {
            (Some(net), true) => net.telemetry(index - self.local_base()),
            _ => crate::net::NetTelemetry::default(),
        };
        WorkerTelemetry {
            worker: index,
            process: self.process_of(index),
            parks: stats.parks.load(Ordering::Relaxed),
            unparks: stats.unparks.load(Ordering::Relaxed),
            ring_full_stalls: stats.ring_full.load(Ordering::Relaxed),
            net,
        }
    }

    /// Snapshots of every worker's counters, in index order (remote
    /// workers' rows are zero — each process observes only its own).
    pub fn telemetry_all(&self) -> Vec<WorkerTelemetry> {
        (0..self.peers).map(|w| self.telemetry(w)).collect()
    }

    /// Registers the *calling* thread as worker `index`'s thread, making it
    /// a wakeup target for [`Fabric::unpark_peers`] (and, in a cluster, for
    /// the net fabric's recv threads). Called by the worker during
    /// construction (workers are built on their own threads); only the
    /// first registration per slot takes effect.
    pub fn register_worker_thread(&self, index: usize) {
        let _ = self.threads[index].set(std::thread::current());
        if let Some(net) = &self.net {
            net.register_waker(index - self.local_base(), std::thread::current());
        }
    }

    /// Unparks every registered worker thread except `except` (the caller).
    ///
    /// Called after pushing progress batches or releasing data messages
    /// into the fabric, so parked peers observe them promptly. Unparking a
    /// running (or finished) thread is harmless; a not-yet-registered
    /// worker cannot have parked, so skipping its empty slot loses nothing.
    pub fn unpark_peers(&self, except: usize) {
        for (index, slot) in self.threads.iter().enumerate() {
            if index == except {
                continue;
            }
            if let Some(thread) = slot.get() {
                self.stats[index].note_unpark();
                thread.unpark();
            }
        }
    }

    /// Unparks one specific (local) worker thread. Used by the serve
    /// command plane: a client pushing a command onto worker `index`'s
    /// ring wakes exactly that worker, so a query arriving at an idle
    /// cluster is answered without waiting out a park timeout. Safe
    /// against lost wakeups for the same reason `unpark_peers` is — an
    /// unpark of a running thread leaves a token its next park consumes.
    pub fn unpark_worker(&self, index: usize) {
        if let Some(thread) = self.threads[index].get() {
            self.stats[index].note_unpark();
            thread.unpark();
        }
    }

    /// Claims the send half of channel `(chan, from, to)`, routed by the
    /// destination's locality: an intra-process ring when `to` is hosted
    /// here, a serializing net endpoint otherwise. Called by (local)
    /// worker `from` exactly once per key.
    pub fn channel_sender<M: Wire + Send + 'static>(
        &self,
        chan: usize,
        from: usize,
        to: usize,
    ) -> FabricSender<M> {
        if self.is_local(to) {
            FabricSender::Ring(self.sender(chan, from, to))
        } else {
            let net = self.net.as_ref().expect("remote peer without a net fabric");
            FabricSender::Net(net.sender(chan, from, to))
        }
    }

    /// Claims the receive half of channel `(chan, from, to)`, routed by
    /// the source's locality. Called by (local) worker `to` exactly once
    /// per key.
    pub fn channel_receiver<M: Wire + Send + 'static>(
        &self,
        chan: usize,
        from: usize,
        to: usize,
    ) -> FabricReceiver<M> {
        if self.is_local(from) {
            FabricReceiver::Ring(self.receiver(chan, from, to))
        } else {
            let net = self.net.as_ref().expect("remote peer without a net fabric");
            FabricReceiver::Net(net.receiver(chan, from, to))
        }
    }

    /// Same-process slice of a broadcast send fan: ring mailbox halves
    /// toward every peer hosted by THIS process (`None` at `from` and at
    /// every remote worker), indexed by peer. The progress plane pairs
    /// this with [`Fabric::progress_net_senders`]: remote processes are
    /// covered by per-process broadcast frames (broadcast dedup), not by
    /// per-worker channels.
    pub fn local_broadcast_senders<M: Send + 'static>(
        &self,
        chan: usize,
        from: usize,
    ) -> Vec<Option<RingSender<M>>> {
        (0..self.peers)
            .map(|to| {
                if to == from || !self.is_local(to) {
                    None
                } else {
                    Some(self.sender(chan, from, to))
                }
            })
            .collect()
    }

    /// One progress broadcast sender per REMOTE process (`None` at this
    /// process; all `None` outside a cluster), indexed by process: the
    /// broadcast-dedup send path — one [`NetBroadcastSender::send`] per
    /// flush per remote process covers every worker it hosts.
    pub fn progress_net_senders<T: Timestamp>(
        &self,
        chan: usize,
        from: usize,
    ) -> Vec<Option<NetBroadcastSender<T>>> {
        (0..self.processes)
            .map(|process| {
                if process == self.process {
                    return None;
                }
                let net = self.net.as_ref().expect("remote process without a net fabric");
                Some(net.broadcast_sender::<T>(chan, from, process))
            })
            .collect()
    }

    /// The progress receive fan for worker `to`, indexed by sending peer:
    /// ring mailbox halves from same-process senders, net endpoints — fed
    /// by the per-process broadcast fan-out — from remote ones. Registers
    /// the channel's fan-out decoder with the net fabric on first call
    /// (idempotent; parked early frames replay in order).
    pub fn progress_receivers<T: Timestamp>(
        &self,
        chan: usize,
        to: usize,
    ) -> Vec<Option<FabricReceiver<std::sync::Arc<ProgressUpdates<T>>>>> {
        if let Some(net) = &self.net {
            net.register_broadcast::<ProgressBroadcast<T>>(chan);
        }
        (0..self.peers)
            .map(|from| {
                if from == to {
                    None
                } else if self.is_local(from) {
                    Some(FabricReceiver::Ring(self.receiver(chan, from, to)))
                } else {
                    let net = self.net.as_ref().expect("remote peer without a net fabric");
                    Some(FabricReceiver::Net(net.receiver(chan, from, to)))
                }
            })
            .collect()
    }

    /// Ring-only broadcast fan-out (no serialization bound): every peer
    /// must be process-local. For single-process harnesses and benches
    /// whose message types cannot cross a process boundary.
    pub fn ring_broadcast_senders<M: Send + 'static>(
        &self,
        chan: usize,
        from: usize,
    ) -> Vec<Option<RingSender<M>>> {
        (0..self.peers)
            .map(|to| if to == from { None } else { Some(self.sender(chan, from, to)) })
            .collect()
    }

    /// Ring-only broadcast fan-in (counterpart of
    /// [`Fabric::ring_broadcast_senders`]).
    pub fn ring_broadcast_receivers<M: Send + 'static>(
        &self,
        chan: usize,
        to: usize,
    ) -> Vec<Option<RingReceiver<M>>> {
        (0..self.peers)
            .map(|from| if from == to { None } else { Some(self.receiver(chan, from, to)) })
            .collect()
    }

    /// Claims the send half of the intra-process ring `(channel, from,
    /// to)`. Both workers must be hosted by this process — engine code
    /// goes through [`Fabric::channel_sender`], which routes by locality.
    /// Called by worker `from` exactly once per key.
    pub fn sender<M: Send + 'static>(&self, chan: usize, from: usize, to: usize) -> RingSender<M> {
        assert!(
            self.is_local(from) && self.is_local(to),
            "ring endpoints must be process-local (use channel_sender)"
        );
        let key = (chan, from, to);
        let mut pending = self.pending.lock().unwrap();
        if let Some(tx) = pending.senders.remove(&key) {
            *tx.downcast::<RingSender<M>>().expect("channel type mismatch")
        } else {
            let (tx, rx) = ring::channel::<M>(self.ring_capacity);
            pending.receivers.insert(key, Box::new(rx));
            tx
        }
    }

    /// Claims the receive half of the intra-process ring `(channel, from,
    /// to)` (see [`Fabric::sender`]). Called by worker `to` exactly once
    /// per key.
    pub fn receiver<M: Send + 'static>(
        &self,
        chan: usize,
        from: usize,
        to: usize,
    ) -> RingReceiver<M> {
        assert!(
            self.is_local(from) && self.is_local(to),
            "ring endpoints must be process-local (use channel_receiver)"
        );
        let key = (chan, from, to);
        let mut pending = self.pending.lock().unwrap();
        if let Some(rx) = pending.receivers.remove(&key) {
            *rx.downcast::<RingReceiver<M>>().expect("channel type mismatch")
        } else {
            let (tx, rx) = ring::channel::<M>(self.ring_capacity);
            pending.senders.insert(key, Box::new(tx));
            rx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sender_first_then_receiver() {
        let fabric = Fabric::new(2);
        let mut tx = fabric.sender::<u32>(0, 0, 1);
        let mut rx = fabric.receiver::<u32>(0, 0, 1);
        tx.send(42).unwrap();
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn receiver_first_then_sender() {
        let fabric = Fabric::new(2);
        let mut rx = fabric.receiver::<u32>(3, 1, 0);
        let mut tx = fabric.sender::<u32>(3, 1, 0);
        tx.send(7).unwrap();
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn distinct_keys_distinct_channels() {
        let fabric = Fabric::new(2);
        let mut tx_a = fabric.sender::<u32>(0, 0, 1);
        let mut tx_b = fabric.sender::<u32>(1, 0, 1);
        let mut rx_a = fabric.receiver::<u32>(0, 0, 1);
        let mut rx_b = fabric.receiver::<u32>(1, 0, 1);
        tx_a.send(1).unwrap();
        tx_b.send(2).unwrap();
        assert_eq!(rx_a.recv().unwrap(), 1);
        assert_eq!(rx_b.recv().unwrap(), 2);
    }

    #[test]
    fn cross_thread_claiming() {
        let fabric = Fabric::new(2);
        let f2 = fabric.clone();
        let handle = std::thread::spawn(move || {
            let mut rx = f2.receiver::<String>(9, 0, 1);
            rx.recv().unwrap()
        });
        let mut tx = fabric.sender::<String>(9, 0, 1);
        tx.send("hello".to_string()).unwrap();
        assert_eq!(handle.join().unwrap(), "hello");
    }

    /// Regression: concurrent sender/receiver resolution across many keys
    /// must not deadlock (the two pending maps once lived under separate
    /// locks, acquired in opposite orders by the two claim paths).
    #[test]
    fn concurrent_claims_do_not_deadlock() {
        for _ in 0..50 {
            let fabric = Fabric::new(2);
            let f2 = fabric.clone();
            let a = std::thread::spawn(move || {
                for chan in 0..64 {
                    let _tx = f2.sender::<u64>(chan, 0, 1);
                    let _rx = f2.receiver::<u64>(chan, 1, 0);
                }
            });
            for chan in 0..64 {
                let _rx = fabric.receiver::<u64>(chan, 0, 1);
                let _tx = fabric.sender::<u64>(chan, 1, 0);
            }
            a.join().unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "type mismatch")]
    fn type_mismatch_panics() {
        let fabric = Fabric::new(2);
        let _tx = fabric.sender::<u32>(0, 0, 1);
        let _rx = fabric.receiver::<String>(0, 0, 1);
    }

    /// The progress plane's single-process fan: local ring senders pair up
    /// with `progress_receivers`' ring halves, `None` on the diagonal.
    #[test]
    fn local_broadcast_fan_matches_pairwise_endpoints() {
        use std::sync::Arc;
        type Batch = Arc<ProgressUpdates<u64>>;
        let fabric = Fabric::new(3);
        let mut senders0 = fabric.local_broadcast_senders::<Batch>(9, 0);
        assert_eq!(senders0.len(), 3);
        assert!(senders0[0].is_none(), "no self channel");
        let mut rx1 = fabric.progress_receivers::<u64>(9, 1);
        let mut rx2 = fabric.progress_receivers::<u64>(9, 2);
        senders0[1].as_mut().unwrap().send(Arc::new(Vec::new())).unwrap();
        senders0[2].as_mut().unwrap().send(Arc::new(Vec::new())).unwrap();
        assert!(rx1[0].as_mut().unwrap().recv().is_ok());
        assert!(rx2[0].as_mut().unwrap().recv().is_ok());
        assert!(rx1[1].is_none() && rx2[2].is_none());
    }

    #[test]
    fn unpark_wakes_a_parked_registered_worker() {
        let fabric = Fabric::new(2);
        let f2 = fabric.clone();
        let worker = std::thread::spawn(move || {
            f2.register_worker_thread(1);
            // Park for up to 5s; the unpark below must cut this short (or
            // land first, making park return immediately via the token).
            let start = std::time::Instant::now();
            std::thread::park_timeout(std::time::Duration::from_secs(5));
            start.elapsed()
        });
        // Give the worker a moment to register and park, then wake it.
        std::thread::sleep(std::time::Duration::from_millis(50));
        fabric.unpark_peers(0);
        let parked_for = worker.join().unwrap();
        assert!(
            parked_for < std::time::Duration::from_secs(4),
            "worker should have been unparked early, parked {parked_for:?}"
        );
        assert_eq!(fabric.telemetry(1).unparks, 1);
    }

    #[test]
    fn unpark_peers_skips_caller_and_unregistered_slots() {
        let fabric = Fabric::new(4);
        fabric.register_worker_thread(2);
        // Workers 0,1,3 never registered; this must not panic and must not
        // unpark the caller's own slot.
        fabric.unpark_peers(2);
        fabric.unpark_peers(0);
        assert_eq!(fabric.telemetry(2).unparks, 1);
        assert_eq!(fabric.telemetry(0).unparks, 0);
    }

    #[test]
    fn custom_ring_capacity_reaches_both_endpoints() {
        let fabric = Fabric::with_ring_capacity(2, 16);
        assert_eq!(fabric.ring_capacity(), 16);
        let tx = fabric.sender::<u32>(0, 0, 1);
        assert_eq!(tx.capacity(), 16);
        // The counterpart half parked by the sender claim has the same
        // depth (one ring, two endpoints).
        let _rx = fabric.receiver::<u32>(0, 0, 1);
        // Degenerate capacities clamp to the ring minimum instead of
        // panicking.
        let tiny = Fabric::with_ring_capacity(2, 0);
        assert_eq!(tiny.sender::<u32>(0, 0, 1).capacity(), 2);
    }

    /// Heterogeneous cluster shapes route by prefix sums, not division:
    /// shape 2+1+1 puts workers {0,1} on process 0, {2} on 1, {3} on 2.
    #[test]
    fn asymmetric_shapes_route_by_prefix_sums() {
        let net = NetFabric::new(1, vec![2, 1, 1], vec![None, None, None], 4);
        let fabric = Fabric::cluster(&[2, 1, 1], 1, 8, net);
        assert_eq!(fabric.peers(), 4);
        assert_eq!(fabric.processes(), 3);
        assert_eq!(
            (0..4).map(|w| fabric.process_of(w)).collect::<Vec<_>>(),
            vec![0, 0, 1, 2]
        );
        assert!(fabric.is_local(2));
        assert!(!fabric.is_local(1) && !fabric.is_local(3));
        assert_eq!(fabric.local_base(), 2);
    }

    #[test]
    fn local_broadcast_senders_skip_remote_workers() {
        let net = NetFabric::new(0, vec![2, 2], vec![None, None], 4);
        let fabric = Fabric::cluster(&[2, 2], 0, 8, net);
        let senders = fabric.local_broadcast_senders::<u64>(5, 0);
        assert_eq!(senders.len(), 4);
        assert!(senders[0].is_none(), "no self channel");
        assert!(senders[1].is_some(), "same-process peer gets a ring");
        assert!(senders[2].is_none() && senders[3].is_none(), "remote workers get none");
    }

    #[test]
    fn telemetry_counters_accumulate() {
        let fabric = Fabric::new(2);
        let stats = fabric.stats(1);
        stats.note_park();
        stats.note_park();
        stats.note_ring_full();
        let t = fabric.telemetry(1);
        assert_eq!((t.parks, t.ring_full_stalls), (2, 1));
        assert_eq!(fabric.telemetry_all().len(), 2);
    }
}
