//! The cross-worker communication fabric.
//!
//! Workers build identical dataflow graphs in the same order, so channel
//! identifiers agree without coordination. Each directed channel instance
//! `(channel, from, to)` is one `std::sync::mpsc` pair; whichever side asks
//! first creates the pair and parks the counterpart half for the other
//! worker to claim.
//!
//! Both pending maps live under ONE mutex: claiming involves looking in one
//! map and inserting into the other, and taking two locks in
//! caller-dependent order deadlocks (worker A resolving a sender while
//! worker B resolves the matching receiver).

use std::any::Any;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

type Key = (usize, usize, usize); // (channel, from, to)

#[derive(Default)]
struct Pending {
    senders: HashMap<Key, Box<dyn Any + Send>>,
    receivers: HashMap<Key, Box<dyn Any + Send>>,
}

/// The shared endpoint registry.
pub struct Fabric {
    peers: usize,
    pending: Mutex<Pending>,
}

impl Fabric {
    /// A fabric for `peers` workers.
    pub fn new(peers: usize) -> std::sync::Arc<Self> {
        std::sync::Arc::new(Fabric { peers, pending: Mutex::new(Pending::default()) })
    }

    /// Number of workers sharing this fabric.
    pub fn peers(&self) -> usize {
        self.peers
    }

    /// Claims the send half of `(channel, from, to)`. Called by worker
    /// `from` exactly once per key.
    pub fn sender<M: Send + 'static>(&self, chan: usize, from: usize, to: usize) -> Sender<M> {
        let key = (chan, from, to);
        let mut pending = self.pending.lock().unwrap();
        if let Some(tx) = pending.senders.remove(&key) {
            *tx.downcast::<Sender<M>>().expect("channel type mismatch")
        } else {
            let (tx, rx) = channel::<M>();
            pending.receivers.insert(key, Box::new(rx));
            tx
        }
    }

    /// Claims the receive half of `(channel, from, to)`. Called by worker
    /// `to` exactly once per key.
    pub fn receiver<M: Send + 'static>(&self, chan: usize, from: usize, to: usize) -> Receiver<M> {
        let key = (chan, from, to);
        let mut pending = self.pending.lock().unwrap();
        if let Some(rx) = pending.receivers.remove(&key) {
            *rx.downcast::<Receiver<M>>().expect("channel type mismatch")
        } else {
            let (tx, rx) = channel::<M>();
            pending.senders.insert(key, Box::new(tx));
            rx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sender_first_then_receiver() {
        let fabric = Fabric::new(2);
        let tx = fabric.sender::<u32>(0, 0, 1);
        let rx = fabric.receiver::<u32>(0, 0, 1);
        tx.send(42).unwrap();
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn receiver_first_then_sender() {
        let fabric = Fabric::new(2);
        let rx = fabric.receiver::<u32>(3, 1, 0);
        let tx = fabric.sender::<u32>(3, 1, 0);
        tx.send(7).unwrap();
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn distinct_keys_distinct_channels() {
        let fabric = Fabric::new(2);
        let tx_a = fabric.sender::<u32>(0, 0, 1);
        let tx_b = fabric.sender::<u32>(1, 0, 1);
        let rx_a = fabric.receiver::<u32>(0, 0, 1);
        let rx_b = fabric.receiver::<u32>(1, 0, 1);
        tx_a.send(1).unwrap();
        tx_b.send(2).unwrap();
        assert_eq!(rx_a.recv().unwrap(), 1);
        assert_eq!(rx_b.recv().unwrap(), 2);
    }

    #[test]
    fn cross_thread_claiming() {
        let fabric = Fabric::new(2);
        let f2 = fabric.clone();
        let handle = std::thread::spawn(move || {
            let rx = f2.receiver::<String>(9, 0, 1);
            rx.recv().unwrap()
        });
        let tx = fabric.sender::<String>(9, 0, 1);
        tx.send("hello".to_string()).unwrap();
        assert_eq!(handle.join().unwrap(), "hello");
    }

    /// Regression: concurrent sender/receiver resolution across many keys
    /// must not deadlock (the two pending maps once lived under separate
    /// locks, acquired in opposite orders by the two claim paths).
    #[test]
    fn concurrent_claims_do_not_deadlock() {
        for _ in 0..50 {
            let fabric = Fabric::new(2);
            let f2 = fabric.clone();
            let a = std::thread::spawn(move || {
                for chan in 0..64 {
                    let _tx = f2.sender::<u64>(chan, 0, 1);
                    let _rx = f2.receiver::<u64>(chan, 1, 0);
                }
            });
            for chan in 0..64 {
                let _rx = fabric.receiver::<u64>(chan, 0, 1);
                let _tx = fabric.sender::<u64>(chan, 1, 0);
            }
            a.join().unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "type mismatch")]
    fn type_mismatch_panics() {
        let fabric = Fabric::new(2);
        let _tx = fabric.sender::<u32>(0, 0, 1);
        let _rx = fabric.receiver::<String>(0, 0, 1);
    }
}
