//! The worker: one thread, one instance of the dataflow graph, one tracker.
//!
//! Each step the worker (1) drains remote messages into local mailboxes,
//! (2) schedules operators that have queued input, changed frontiers, or an
//! activation request, draining the shared token bookkeeping after each so
//! the accumulated changes reflect atomic operator actions (§4), (3) when
//! the flush cadence is due, broadcasts its coalesced atomic batch through
//! its [`Progcaster`]'s per-peer FIFO ring mailboxes (same-process peers)
//! and one per-process broadcast frame per remote process (the net
//! fabric's dedup fan-out), and THEN releases staged remote data messages,
//! and (4) folds every batch arriving on its own mailboxes (its loopback
//! included) into its tracker.
//!
//! # Step ordering and conservatism
//!
//! There is no sequenced log and no global order on progress batches. The
//! two orderings the step loop *does* enforce are exactly the ones prefix
//! safety needs (see [`crate::progress::exchange`] for the full argument):
//!
//! * **per-sender FIFO** — one worker's batches enter every peer mailbox
//!   in the same order, and bookkeeping is drained after each operator
//!   action, so each stream reflects that worker's true action order;
//! * **produce-before-data-release** — the progress batch carrying a
//!   message's `+1` produce count is broadcast *before* the staged message
//!   is released to the data fabric, so no consumer can account a message
//!   whose produce count is not already in every observer's mailbox.
//!
//! Both fabric planes ride the same bounded SPSC rings ([`ring`]), so
//! backpressure is explicit: a full progress mailbox parks the batch in
//! the progcaster's FIFO spill queue — and data release is *gated* on the
//! spill being empty, since a spilled batch's produce counts have not
//! reached every mailbox yet; a full data ring keeps messages staged in
//! the channel (also FIFO) and the worker retries next flush. Holding a
//! message longer is always conservative, so neither case threatens
//! safety, and both resolve because every live worker drains its rings
//! each step. Idle workers don't busy-spin: [`Worker::step_or_park`] parks
//! the thread, and peers unpark it whenever they push progress or data
//! into the fabric. Parks, unparks, and ring-full stalls are counted per
//! worker ([`Worker::telemetry`]) and surfaced by the harness reports.

pub mod allocator;
pub mod execute;
pub mod ring;

use crate::dataflow::channels::Data;
use crate::dataflow::input::InputSession;
use crate::dataflow::scope::{BuildState, OpCore, Scope};
use crate::dataflow::stream::Stream;
use crate::dataflow::token::BookkeepingHandle;
use crate::progress::exchange::{Progcaster, ProgressBatch};
use crate::progress::location::Location;
use crate::progress::timestamp::Timestamp;
use crate::progress::tracker::Tracker;
use allocator::{Fabric, WorkerStats, WorkerTelemetry};
use std::cell::Cell;
use std::rc::Rc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default progress-flush cadence: how long a worker may sit on pending
/// progress updates (token downgrades, message accounting) and staged
/// remote data before broadcasting them and releasing the fabric.
/// Coalescing is what keeps fine timestamp quanta (2^8 ns in Figure 6/7)
/// from turning every scheduling step into a broadcast; the cost is a
/// bounded addition to the completion-latency floor. With per-peer SPSC
/// mailboxes there is no contention to adapt to, so the cadence is a
/// constant — configurable per run through `Config::progress_flush`
/// (swept by `micro_progress --sweep-cadence`).
pub const PROGRESS_FLUSH: Duration = Duration::from_micros(20);

/// Pending updates beyond this force an immediate flush (bounds memory and
/// peer latency under bursts, independent of the cadence).
const FLUSH_BATCH_LIMIT: usize = 4096;

/// Default park bound for [`Worker::step_or_park`] as used by
/// [`Worker::step_while`]: an upper bound only — peers unpark the worker
/// the moment they push progress or data for it.
pub const PARK_TIMEOUT: Duration = Duration::from_micros(500);

/// A dataflow worker. Generic over the dataflow's timestamp type.
pub struct Worker<T: Timestamp> {
    scope: Scope<T>,
    fabric: Arc<Fabric>,
    /// This worker's endpoint of the decentralized progress plane.
    progcaster: Progcaster<T>,
    tracker: Option<Tracker<T>>,
    ops: Vec<OpCore<T>>,
    drainers: Vec<Box<dyn FnMut() -> bool>>,
    flushers: Vec<Box<dyn FnMut() -> (bool, bool)>>,
    /// The worker-wide shared bookkeeping, cached off `scope.state` so the
    /// step hot loop never re-borrows the build state (and never clones
    /// the underlying `Rc`) — it used to do both up to three times per
    /// step.
    bookkeeping: BookkeepingHandle<T>,
    /// The channels' remote-staged latch, cached for the same reason.
    staged_latch: Rc<Cell<bool>>,
    /// Scratch: bookkeeping drain target, moved into the progcaster.
    scratch: Vec<((Location, T), i64)>,
    read_buf: Vec<Arc<ProgressBatch<T>>>,
    steps: u64,
    /// Remote data staged since the last flush (must be released together
    /// with — after — the broadcast carrying its produce counts).
    remote_pending: bool,
    /// When this worker last flushed (broadcast + fabric release).
    last_flush: Instant,
    /// Progress-flush cadence (defaults to [`PROGRESS_FLUSH`]).
    progress_flush: Duration,
    /// Shared tuning state when the net governor is running
    /// (`Config::autotune`): the worker re-reads its flush cadence
    /// whenever the generation stamp moves.
    tune: Option<Arc<crate::net::tune::TuneShared>>,
    /// The last tune generation this worker applied.
    tune_generation: u64,
    /// This worker's fabric telemetry counters.
    stats: Arc<WorkerStats>,
    /// Checkpoint/restore context (u64-timestamped dataflows only): the
    /// step loop drives its continuous sealing with the tracker's global
    /// frontier bound. `None` (the default) costs the step loop nothing.
    recovery: Option<Rc<crate::recovery::RecoveryContext>>,
    /// Event tracer (observability plane): the step loop emits operator
    /// activation spans, frontier/epoch events, park spans, and progress
    /// timing through it. `None` (the default) costs one branch per hook
    /// (see `observe` module docs).
    tracer: Option<Rc<crate::observe::WorkerTracer>>,
    /// Set by [`Worker::poison`]: simulates a process crash by skipping
    /// the orderly final flush on drop.
    poisoned: bool,
}

impl<T: Timestamp> Worker<T> {
    /// Creates a worker bound to a fabric, claiming its progress mailboxes
    /// and registering the calling thread for peer wakeups. Most users go
    /// through [`execute::execute`].
    pub fn new(index: usize, peers: usize, fabric: Arc<Fabric>) -> Self {
        fabric.register_worker_thread(index);
        let progcaster = Progcaster::new(index, peers, &fabric);
        let stats = fabric.stats(index);
        let scope = Scope::new(BuildState::new(index, peers, fabric.clone()));
        // Cache the two shared handles the step loop touches constantly;
        // both are created once by `BuildState::new` and never replaced.
        let (bookkeeping, staged_latch) = {
            let state = scope.state.borrow();
            (state.bookkeeping.clone(), state.remote_staged.clone())
        };
        Worker {
            scope,
            fabric,
            progcaster,
            tracker: None,
            ops: Vec::new(),
            drainers: Vec::new(),
            flushers: Vec::new(),
            bookkeeping,
            staged_latch,
            scratch: Vec::new(),
            read_buf: Vec::new(),
            steps: 0,
            remote_pending: false,
            last_flush: Instant::now(),
            progress_flush: PROGRESS_FLUSH,
            tune: None,
            tune_generation: 0,
            stats,
            recovery: None,
            tracer: None,
            poisoned: false,
        }
    }

    /// The shared fabric (peer wakeups, telemetry; the serve plane
    /// grabs it here to route client unparks at build time).
    pub fn fabric(&self) -> &Arc<Fabric> {
        &self.fabric
    }

    /// This worker's index.
    pub fn index(&self) -> usize {
        self.scope.index()
    }

    /// Total number of workers.
    pub fn peers(&self) -> usize {
        self.scope.peers()
    }

    /// The dataflow build scope (for operator builders).
    pub fn scope(&self) -> Scope<T> {
        self.scope.clone()
    }

    /// Overrides the progress-flush cadence (see `Config::progress_flush`).
    pub fn set_progress_flush(&mut self, cadence: Duration) {
        self.progress_flush = cadence;
    }

    /// Attaches the governor's shared tuning state (`Config::autotune`):
    /// from now on the flush cadence follows its online adjustments.
    pub fn set_tune(&mut self, tune: Option<Arc<crate::net::tune::TuneShared>>) {
        if let Some(t) = &tune {
            self.tune_generation = t.generation();
            self.progress_flush = t.progress_flush();
        }
        self.tune = tune;
    }

    /// Overrides the output batch size for operators built *after* this
    /// call (see `Config::send_batch`).
    pub fn set_send_batch(&mut self, records: usize) {
        self.scope.state.borrow_mut().send_batch = records.max(1);
    }

    /// A snapshot of this worker's fabric counters (parks, unparks,
    /// ring-full stalls, and — in a cluster — the net-plane counters).
    pub fn telemetry(&self) -> WorkerTelemetry {
        self.fabric.telemetry(self.progcaster.index())
    }

    /// The process hosting this worker (0 outside a cluster).
    pub fn process(&self) -> usize {
        self.fabric.process()
    }

    /// The effective progress-flush cadence (config-propagation checks).
    pub fn progress_flush(&self) -> Duration {
        self.progress_flush
    }

    /// The fabric's effective ring capacity (config-propagation checks).
    pub fn ring_capacity(&self) -> usize {
        self.fabric.ring_capacity()
    }

    /// The effective output batch size (config-propagation checks).
    pub fn send_batch(&self) -> usize {
        self.scope.state.borrow().send_batch
    }

    /// True iff the governor's shared tuning handle reached this worker —
    /// set only when the (handshake-propagated) `autotune` flag is on, so
    /// cluster tests can pin that process 0's flag arrived everywhere.
    pub fn autotune_enabled(&self) -> bool {
        self.tune.is_some()
    }

    /// How many net I/O threads serve this worker's process (0 outside a
    /// cluster; 1 under the reactor; `2·(P−1)` under the legacy thread-pair
    /// transport). Exposed so cluster tests can pin the thread budget.
    pub fn net_io_threads(&self) -> usize {
        self.fabric.net().map_or(0, |net| net.io_threads())
    }

    /// Peer processes observed to die abruptly mid-run (always empty for
    /// a single process). A nonempty answer means frontiers can no longer
    /// advance past epochs the dead peer's workers were feeding: drivers
    /// should [`Worker::poison`] this worker, report, and restart the
    /// cluster from the last complete checkpoint (`ttd --recover`)
    /// instead of stepping forever.
    pub fn lost_peers(&self) -> Vec<usize> {
        self.fabric.lost_peers()
    }

    /// [`Worker::step_while`], but bailing out — after poisoning this
    /// worker — if a peer process dies first. The poison matters: a
    /// survivor's final flush would otherwise block on rings nobody
    /// drains. Returns `Ok(())` when `active` went false, or the typed
    /// loss condition.
    pub fn step_while_surviving(
        &mut self,
        mut active: impl FnMut() -> bool,
    ) -> Result<(), crate::net::NetError> {
        self.finalize();
        while active() {
            if let Some(&process) = self.lost_peers().first() {
                self.poison();
                return Err(crate::net::NetError::PeerLost { process });
            }
            self.step_or_park(PARK_TIMEOUT);
        }
        self.flush_now();
        Ok(())
    }

    /// Installs a checkpoint/restore context: stateful operators built
    /// after this call register their state cells with it, and every step
    /// drives its frontier-aligned sealing/capture. Must be called before
    /// graph construction. Only meaningful for `u64`-timestamped dataflows
    /// (the step hook reads the tracker's frontier as `u64`); installing
    /// one on any other timestamp type is a no-op at step time.
    pub fn set_recovery(&mut self, ctx: Rc<crate::recovery::RecoveryContext>) {
        assert!(self.tracker.is_none(), "recovery must be installed before the dataflow starts");
        self.scope.state.borrow_mut().recovery = Some(ctx.clone());
        self.recovery = Some(ctx);
    }

    /// Installs an event tracer: operators built after this call count
    /// records through it, and every step emits activation spans, epoch
    /// transitions, progress timing, and park spans. Must be called before
    /// graph construction. Epoch attribution is only meaningful for
    /// `u64`-timestamped dataflows (the step hook reads the tracker's
    /// frontier as `u64`); other timestamp types still get spans.
    pub fn set_tracer(&mut self, tracer: Rc<crate::observe::WorkerTracer>) {
        assert!(self.tracker.is_none(), "tracer must be installed before the dataflow starts");
        self.scope.state.borrow_mut().tracer = Some(tracer.clone());
        self.progcaster.set_tracer(tracer.clone());
        self.tracer = Some(tracer);
    }

    /// The epoch a recovered dataflow resumes from: inputs must replay
    /// from the *next* epoch (state already reflects everything at
    /// `<= resume_epoch()`). 0 when not recovering.
    pub fn resume_epoch(&self) -> u64 {
        self.recovery.as_ref().map(|c| c.resume_epoch()).unwrap_or(0)
    }

    /// Simulates a process crash for fault-injection tests: the worker
    /// stops participating in the orderly shutdown protocol (no final
    /// flush on drop), exactly as if its process had been SIGKILLed
    /// mid-step.
    pub fn poison(&mut self) {
        self.poisoned = true;
    }

    /// Simulates a hard crash of this worker's *process* for
    /// fault-injection tests: severs the net fabric — outbound queues
    /// die mid-frame with no drain and no goodbye, so peers observe the
    /// abrupt end-of-stream a SIGKILL produces — and poisons this
    /// worker. Other local workers keep running until they notice (their
    /// sends return `Disconnected`); chaos schedules poison them at the
    /// same injection point.
    pub fn sever_net(&mut self) {
        if let Some(net) = self.fabric.net() {
            net.sever();
        }
        self.poison();
    }

    /// Creates a new dataflow input; returns the session used to feed and
    /// advance it, and the stream of its records.
    pub fn new_input<D: Data>(&mut self) -> (InputSession<T, D>, Stream<T, D>) {
        assert!(self.tracker.is_none(), "cannot add inputs after the dataflow started");
        InputSession::new(&self.scope)
    }

    /// Number of steps taken.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Finalizes graph construction: builds the tracker (seeding initial
    /// token counts) and takes ownership of the registered operators.
    /// Called automatically by the first `step`.
    pub fn finalize(&mut self) {
        if self.tracker.is_some() {
            return;
        }
        let mut state = self.scope.state.borrow_mut();
        state.finalized = true;
        let peers = state.peers;
        let topology = std::mem::take(&mut state.topology);
        let handles = std::mem::take(&mut state.frontier_handles);
        self.ops = std::mem::take(&mut state.ops);
        self.drainers = std::mem::take(&mut state.drainers);
        self.flushers = std::mem::take(&mut state.flushers);
        drop(state);
        let tracker = Tracker::new_with(&topology, peers, handles);
        // Restore topology for diagnostics.
        self.scope.state.borrow_mut().topology = topology;
        self.tracker = Some(tracker);
        // Register operator names with the trace plane (build time, off
        // the hot path — this is the tracer's only allocating call).
        if let Some(tracer) = &self.tracer {
            for op in &self.ops {
                tracer.register_op(op.node as u64, &op.name);
            }
        }
    }

    /// Runs one scheduling step; returns true iff any work happened.
    /// Never blocks (see [`Worker::step_or_park`] for the parking variant).
    pub fn step(&mut self) -> bool {
        self.finalize();
        self.steps += 1;
        let mut active = false;

        // (1) Remote messages into local mailboxes.
        for drain in &mut self.drainers {
            active |= drain();
        }

        // (2a) Input-session (and other out-of-band) token actions.
        self.stage_pending();

        // (2b) Schedule operators. The run decision is fully lazy: an
        // activation request suffices on its own, the frontier scan runs
        // only without one, and the (potentially costly) work hint is
        // consulted only when neither already forces a run. `changed`
        // flags are cleared only for operators that actually run, so a
        // frontier change observed while an operator is skipped for other
        // reasons is never silently absorbed.
        for op in &mut self.ops {
            let should_run = match &self.tracer {
                // Traced: the frontier scan runs unconditionally so the
                // trace records every frontier delivery, not only the
                // ones that decided scheduling.
                Some(tracer) => {
                    let frontier_changed =
                        op.frontiers.iter().any(|f| f.borrow().changed);
                    if frontier_changed {
                        tracer.instant(
                            crate::observe::EventKind::FrontierAdvance,
                            op.node as u64,
                            0,
                        );
                    }
                    op.activation.get() || frontier_changed || (op.work_hint)()
                }
                None => {
                    op.activation.get()
                        || op.frontiers.iter().any(|f| f.borrow().changed)
                        || (op.work_hint)()
                }
            };
            if should_run {
                op.activation.set(false);
                for f in &op.frontiers {
                    f.borrow_mut().changed = false;
                }
                match &self.tracer {
                    Some(tracer) => {
                        let t0 = tracer.now_ns();
                        let (in0, out0) = tracer.io_marks();
                        (op.logic)();
                        let dur = tracer.now_ns().saturating_sub(t0);
                        let (in1, out1) = tracer.io_marks();
                        tracer.emit(
                            crate::observe::EventKind::OpSpan,
                            t0,
                            dur,
                            op.node as u64,
                            crate::observe::pack_io(in1 - in0, out1 - out0),
                        );
                    }
                    None => (op.logic)(),
                }
                self.bookkeeping.drain_into(&mut self.scratch);
                self.progcaster.extend(self.scratch.drain(..));
                active = true;
            }
        }

        // (3) Flush policy. Progress batches and staged remote data move
        // on one cadence: every `progress_flush` the worker broadcasts its
        // coalesced batch into the per-peer ring mailboxes and THEN
        // releases staged fabric messages, so a batch's `+1` produce
        // counts always precede the data they cover
        // (produce-before-data-release). Coalescing across steps lets
        // produce/consume pairs cancel inside the ChangeBatch before ever
        // crossing a thread boundary.
        self.stage_pending();
        // Governor-adjusted cadence: one Acquire load per step; the
        // cadence is re-read only when the generation stamp moved.
        if let Some(tune) = &self.tune {
            let generation = tune.generation();
            if generation != self.tune_generation {
                self.tune_generation = generation;
                self.progress_flush = tune.progress_flush();
            }
        }
        let have_work = self.progcaster.has_updates()
            || self.remote_pending
            || self.progcaster.has_spill();
        let big = self.progcaster.pending_len() >= FLUSH_BATCH_LIMIT;
        if big || (have_work && self.last_flush.elapsed() >= self.progress_flush) {
            active |= self.flush();
        }

        // (4) Fold everything newly arrived (loopback included) into the
        // tracker, one atomic batch at a time.
        let apply_t0 = self.tracer.as_ref().map(|t| t.now_ns());
        let applied = self.apply_inbound();
        if applied {
            if let (Some(tracer), Some(t0)) = (&self.tracer, apply_t0) {
                let dur = tracer.now_ns().saturating_sub(t0);
                tracer.emit(crate::observe::EventKind::ProgressApply, t0, dur, 0, 0);
            }
        }
        active |= applied;

        // (5) Frontier hooks (u64 dataflows only — both read the tracker's
        // global bound as `u64`; other timestamp types skip).
        if self.tracer.is_some() || self.recovery.is_some() {
            let tracker = self.tracker.as_ref().expect("finalized");
            if let Some(tracker) =
                (tracker as &dyn std::any::Any).downcast_ref::<Tracker<u64>>()
            {
                let bound = tracker.min_frontier().copied();
                // (5a) Epoch transition: the tracer's current-epoch stamp
                // follows the min frontier; each observed transition closes
                // the outgoing epoch's attribution window.
                if let Some(tracer) = &self.tracer {
                    let next = bound.unwrap_or(crate::observe::NO_EPOCH);
                    let prev = tracer.epoch();
                    if next != prev {
                        if prev != crate::observe::NO_EPOCH {
                            tracer.emit_at(
                                crate::observe::EventKind::EpochClose,
                                tracer.now_ns(),
                                0,
                                prev,
                                next,
                                0,
                            );
                        }
                        // First observation adopts the frontier silently
                        // (nothing before it is attributable).
                        tracer.set_epoch(next);
                    }
                }
                // (5b) Checkpoint hook: with a recovery context installed,
                // drive its continuous sealing. Sealing is incremental and
                // allocation-free; captures fire only when the bound
                // passes a checkpoint boundary.
                if let Some(ctx) = &self.recovery {
                    match &self.tracer {
                        Some(tracer) => {
                            let t0 = tracer.now_ns();
                            let taken0 = ctx.checkpoints_taken();
                            ctx.on_frontier(bound);
                            let dur = tracer.now_ns().saturating_sub(t0);
                            let taken = ctx.checkpoints_taken() - taken0;
                            if taken > 0 {
                                tracer.emit(
                                    crate::observe::EventKind::CheckpointCapture,
                                    t0,
                                    dur,
                                    taken,
                                    0,
                                );
                            } else if dur >= 1_000 {
                                // Sub-microsecond sealing bookkeeping is
                                // noise; only notable seal work is traced.
                                tracer.emit(
                                    crate::observe::EventKind::CheckpointSeal,
                                    t0,
                                    dur,
                                    0,
                                    0,
                                );
                            }
                        }
                        None => ctx.on_frontier(bound),
                    }
                }
            }
        }

        active
    }

    /// The staging protocol's single entry point: drains out-of-band token
    /// actions from the shared bookkeeping into the progcaster's pending
    /// batch and latches the remote-staged flag. Idempotent; called before
    /// every flush decision (and once before operators run, so input
    /// actions taken between steps join this step's batch).
    fn stage_pending(&mut self) {
        self.bookkeeping.drain_into(&mut self.scratch);
        self.progcaster.extend(self.scratch.drain(..));
        self.remote_pending |= self.staged_latch.replace(false);
    }

    /// Broadcasts the pending batch and — if every batch (this one and any
    /// earlier spill) actually reached the peer mailboxes — releases staged
    /// remote data, then wakes parked peers if anything went out. Returns
    /// true iff anything did.
    fn flush(&mut self) -> bool {
        let sent = self.progcaster.send().is_some();
        let spill_moved = self.progcaster.flush_spill();
        let mut released = false;
        if !self.progcaster.has_spill() {
            // Every produce count is now in every peer's mailbox: staged
            // data may follow it into the fabric
            // (produce-before-data-release). A full *data* ring keeps its
            // messages staged; the latch stays set and we retry next flush.
            let mut remaining = false;
            for flush in &mut self.flushers {
                let (s, r) = flush();
                released |= s;
                remaining |= r;
            }
            self.remote_pending = remaining;
        }
        // else: a progress batch is still spilled behind a full mailbox —
        // data it covers must wait with it (remote_pending stays latched).
        self.last_flush = Instant::now();
        if sent || spill_moved || released {
            self.fabric.unpark_peers(self.progcaster.index());
            if let Some(tracer) = &self.tracer {
                tracer.instant(
                    crate::observe::EventKind::Unpark,
                    released as u64,
                    spill_moved as u64,
                );
            }
        }
        sent || released
    }

    /// Applies every batch waiting on this worker's mailboxes to the
    /// tracker. Returns true iff any batch arrived.
    fn apply_inbound(&mut self) -> bool {
        if !self.progcaster.recv_into(&mut self.read_buf) {
            return false;
        }
        let tracker = self.tracker.as_mut().expect("finalized");
        for batch in self.read_buf.drain(..) {
            tracker.apply_batch(&batch);
        }
        true
    }

    /// Forces the pending progress batch into the peer mailboxes and
    /// releases any staged remote data, retrying through ring
    /// backpressure.
    ///
    /// MUST run before a worker stops stepping (and runs automatically at
    /// the end of [`step_while`](Worker::step_while) and on drop): with the
    /// coalesced flush cadence, a worker can observe its own completion
    /// while still holding staged messages — e.g. the final broadcast
    /// watermarks — that its peers need in order to complete themselves.
    /// The retry loop keeps draining inbound rings (progress *and* data)
    /// so mutual backpressure between finishing workers always resolves;
    /// disconnected peers shed their traffic automatically.
    pub fn flush_now(&mut self) {
        if self.tracker.is_none() || self.poisoned {
            return;
        }
        self.stage_pending();
        // Generous bound: only pathological schedules (a peer neither
        // stepping nor shutting down for seconds) can reach it, and giving
        // up merely leaves data staged — conservative, never unsafe.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if self.progcaster.has_updates()
                || self.remote_pending
                || self.progcaster.has_spill()
            {
                self.flush();
            }
            self.apply_inbound();
            if !self.remote_pending && !self.progcaster.has_spill() {
                break;
            }
            // Keep our own rings moving while we wait for the peer's.
            for drain in &mut self.drainers {
                drain();
            }
            if Instant::now() >= deadline {
                break;
            }
            // Brief sleep between retries: backpressure clears on the
            // peer's timescale, so a hot spin would only burn the core
            // (and inflate the ring-full stall counter).
            std::thread::park_timeout(Duration::from_micros(50));
        }
    }

    /// Like [`Worker::step`], but parks the thread (up to `timeout`) when
    /// the step found nothing to do and nothing is pending, instead of
    /// returning immediately. Peers unpark this worker whenever they push
    /// progress batches or release data messages for it, so the timeout is
    /// a robustness bound, not the wakeup mechanism. Pending-but-unflushed
    /// work is flushed rather than slept on. Returns true iff work
    /// happened.
    pub fn step_or_park(&mut self, timeout: Duration) -> bool {
        if self.step() {
            return true;
        }
        if self.progcaster.has_updates()
            || self.remote_pending
            || self.progcaster.has_spill()
        {
            // Never park on coalesced work peers may be waiting for: one
            // non-blocking flush attempt. If ring backpressure holds some
            // of it (rare), returning true keeps the caller stepping —
            // each step retries and drains inbound — instead of spinning
            // hot inside a retry loop here.
            self.flush();
            self.apply_inbound();
            return true;
        }
        // Safe against lost wakeups: an unpark issued since the (empty)
        // mailbox drain in `step` left a token, making this return
        // immediately.
        self.stats.note_park();
        match &self.tracer {
            Some(tracer) => {
                let t0 = tracer.now_ns();
                std::thread::park_timeout(timeout);
                let dur = tracer.now_ns().saturating_sub(t0);
                tracer.emit(crate::observe::EventKind::Park, t0, dur, 0, 0);
            }
            None => std::thread::park_timeout(timeout),
        }
        false
    }

    /// Steps until `done` returns true, parking while idle.
    ///
    /// Finalizes first: probe frontiers are only meaningful once the
    /// tracker has seeded the initial token counts. Flushes on exit so
    /// peers never wait on updates this worker is still holding.
    pub fn step_while<F: FnMut() -> bool>(&mut self, mut more: F) {
        self.finalize();
        while more() {
            self.step_or_park(PARK_TIMEOUT);
        }
        self.flush_now();
    }

    /// True iff no pointstamps remain anywhere (the dataflow is complete).
    pub fn is_complete(&self) -> bool {
        self.tracker.as_ref().map(|t| t.is_complete()).unwrap_or(false)
    }
}

impl<T: Timestamp> Drop for Worker<T> {
    fn drop(&mut self) {
        // Covers custom driving loops that exit without `step_while`.
        // A poisoned worker simulates a crash: no parting flush.
        if !self.poisoned {
            self.flush_now();
        }
    }
}
