//! The worker: one thread, one instance of the dataflow graph, one tracker.
//!
//! Each step the worker (1) drains remote messages into local mailboxes,
//! (2) schedules operators that have queued input, changed frontiers, or an
//! activation request, draining the shared token bookkeeping after each so
//! the drained changes reflect atomic operator actions (§4), (3) appends
//! its accumulated atomic batch to the sequenced progress log and reads
//! everything new, (4) folds the read batches into its tracker, and (5)
//! releases staged remote data messages (whose `+1` produce counts are now
//! in the log — the ordering that makes every log prefix conservative).

pub mod allocator;
pub mod execute;

use crate::dataflow::channels::Data;
use crate::dataflow::input::InputSession;
use crate::dataflow::scope::{BuildState, OpCore, Scope};
use crate::dataflow::stream::Stream;
use crate::progress::exchange::{ProgressBatch, ProgressLog};
use crate::progress::location::Location;
use crate::progress::timestamp::Timestamp;
use crate::progress::tracker::Tracker;
use allocator::Fabric;
use std::sync::Arc;
use std::time::Instant;

/// Base progress-flush cadence: how long a worker may sit on pending
/// progress updates (token downgrades, message accounting) and staged
/// remote data before pushing them to the sequenced log and fabric.
/// Coalescing is what keeps fine timestamp quanta (2^8 ns in Figure 6/7)
/// from turning every scheduling step into a contended log append; the
/// cost is a bounded addition to the completion-latency floor. The cadence
/// adapts upward (to [`PROGRESS_FLUSH_MAX`]) under contention — many
/// workers all flushing at the base rate saturate the log's total order.
pub const PROGRESS_FLUSH: std::time::Duration = std::time::Duration::from_micros(20);

/// Upper bound for the adaptive flush cadence.
pub const PROGRESS_FLUSH_MAX: std::time::Duration = std::time::Duration::from_micros(320);

/// A dataflow worker. Generic over the dataflow's timestamp type.
pub struct Worker<T: Timestamp> {
    scope: Scope<T>,
    log: Arc<ProgressLog<T>>,
    tracker: Option<Tracker<T>>,
    ops: Vec<OpCore<T>>,
    drainers: Vec<Box<dyn FnMut() -> bool>>,
    flushers: Vec<Box<dyn FnMut()>>,
    local_batch: Vec<((Location, T), i64)>,
    read_buf: Vec<Arc<ProgressBatch<T>>>,
    steps: u64,
    /// This worker's read cursor into the progress log (fast-path skip).
    cursor: usize,
    /// Remote data staged since the last flush (must be released together
    /// with — after — the append carrying its produce counts).
    remote_pending: bool,
    /// When this worker last flushed (append + fabric release).
    last_flush: Instant,
    /// Adaptive flush cadence (see [`PROGRESS_FLUSH`]).
    flush_interval: std::time::Duration,
}

impl<T: Timestamp> Worker<T> {
    /// Creates a worker bound to a fabric and progress log. Most users go
    /// through [`execute::execute`].
    pub fn new(index: usize, peers: usize, fabric: Arc<Fabric>, log: Arc<ProgressLog<T>>) -> Self {
        Worker {
            scope: Scope::new(BuildState::new(index, peers, fabric)),
            log,
            tracker: None,
            ops: Vec::new(),
            drainers: Vec::new(),
            flushers: Vec::new(),
            local_batch: Vec::new(),
            read_buf: Vec::new(),
            steps: 0,
            cursor: 0,
            remote_pending: false,
            last_flush: Instant::now(),
            flush_interval: PROGRESS_FLUSH,
        }
    }

    /// This worker's index.
    pub fn index(&self) -> usize {
        self.scope.index()
    }

    /// Total number of workers.
    pub fn peers(&self) -> usize {
        self.scope.peers()
    }

    /// The dataflow build scope (for operator builders).
    pub fn scope(&self) -> Scope<T> {
        self.scope.clone()
    }

    /// Creates a new dataflow input; returns the session used to feed and
    /// advance it, and the stream of its records.
    pub fn new_input<D: Data>(&mut self) -> (InputSession<T, D>, Stream<T, D>) {
        assert!(self.tracker.is_none(), "cannot add inputs after the dataflow started");
        InputSession::new(&self.scope)
    }

    /// Number of steps taken.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Finalizes graph construction: builds the tracker (seeding initial
    /// token counts) and takes ownership of the registered operators.
    /// Called automatically by the first `step`.
    pub fn finalize(&mut self) {
        if self.tracker.is_some() {
            return;
        }
        let mut state = self.scope.state.borrow_mut();
        state.finalized = true;
        let peers = state.peers;
        let topology = std::mem::take(&mut state.topology);
        let handles = std::mem::take(&mut state.frontier_handles);
        self.ops = std::mem::take(&mut state.ops);
        self.drainers = std::mem::take(&mut state.drainers);
        self.flushers = std::mem::take(&mut state.flushers);
        drop(state);
        let tracker = Tracker::new_with(&topology, peers, handles);
        // Restore topology for diagnostics.
        self.scope.state.borrow_mut().topology = topology;
        self.tracker = Some(tracker);
    }

    /// Runs one scheduling step; returns true iff any work happened.
    pub fn step(&mut self) -> bool {
        self.finalize();
        self.steps += 1;
        let mut active = false;

        // (1) Remote messages into local mailboxes.
        for drain in &mut self.drainers {
            active |= drain();
        }

        // (2a) Input-session (and other out-of-band) token actions.
        let bookkeeping = self.scope.state.borrow().bookkeeping.clone();
        bookkeeping.drain_into(&mut self.local_batch);

        // (2b) Schedule operators.
        for op in &mut self.ops {
            let frontier_changed = op.frontiers.iter().any(|f| f.borrow().changed);
            let should_run = op.activation.get() || frontier_changed || (op.work_hint)();
            if should_run {
                op.activation.set(false);
                for f in &op.frontiers {
                    f.borrow_mut().changed = false;
                }
                (op.logic)();
                bookkeeping.drain_into(&mut self.local_batch);
                active = true;
            }
        }

        // (3) Flush policy. Progress batches and staged remote data move on
        // one cadence: every PROGRESS_FLUSH the worker appends its batch to
        // the sequenced log and THEN releases staged fabric messages, so a
        // batch's `+1` produce counts always precede the data they cover.
        // Coalescing across steps lets produce/consume pairs cancel inside
        // the ChangeBatch before ever touching the shared log — without it,
        // fine timestamp quanta (2^8 ns, Figures 6/7) turn every scheduling
        // step into a contended append. An empty-handed worker skips the
        // log lock entirely while the atomic tail shows nothing new.
        self.remote_pending |= {
            let state = self.scope.state.borrow();
            state.remote_staged.replace(false)
        };
        let have_work = !self.local_batch.is_empty() || self.remote_pending;
        let big = self.local_batch.len() >= 4096;
        let due = big || (have_work && self.last_flush.elapsed() >= self.flush_interval);
        if due {
            let batch = std::mem::take(&mut self.local_batch);
            self.cursor = self.log.append_and_read(self.index(), batch, &mut self.read_buf);
            // Adapt the cadence to the observed log traffic: a backlog of
            // whole-fleet batches per flush means everyone is hammering the
            // total order — back off; an idle log invites lower latency.
            let peers = self.peers();
            if self.read_buf.len() > 4 * peers {
                self.flush_interval = (self.flush_interval * 2).min(PROGRESS_FLUSH_MAX);
            } else if self.read_buf.len() <= peers {
                self.flush_interval = (self.flush_interval / 2).max(PROGRESS_FLUSH);
            }
            // (4) Fold everything new into the tracker.
            let tracker = self.tracker.as_mut().expect("finalized");
            for batch in self.read_buf.drain(..) {
                tracker.apply(batch.iter().cloned());
            }
            // (5) Release staged remote messages (their +1s are now logged).
            for flush in &mut self.flushers {
                flush();
            }
            self.remote_pending = false;
            self.last_flush = Instant::now();
            active = true;
        } else if self.cursor != self.log.tail() {
            self.cursor =
                self.log.append_and_read(self.index(), Vec::new(), &mut self.read_buf);
            let tracker = self.tracker.as_mut().expect("finalized");
            for batch in self.read_buf.drain(..) {
                tracker.apply(batch.iter().cloned());
            }
            active = true;
        }

        active
    }

    /// Forces the pending progress batch into the sequenced log and
    /// releases any staged remote data.
    ///
    /// MUST run before a worker stops stepping (and runs automatically at
    /// the end of [`step_while`](Worker::step_while) and on drop): with the
    /// coalesced flush cadence, a worker can observe its own completion
    /// while still holding staged messages — e.g. the final broadcast
    /// watermarks — that its peers need in order to complete themselves.
    pub fn flush_now(&mut self) {
        if self.tracker.is_none() {
            return;
        }
        let bookkeeping = self.scope.state.borrow().bookkeeping.clone();
        bookkeeping.drain_into(&mut self.local_batch);
        self.remote_pending |= {
            let state = self.scope.state.borrow();
            state.remote_staged.replace(false)
        };
        if !self.local_batch.is_empty() || self.remote_pending {
            let batch = std::mem::take(&mut self.local_batch);
            self.cursor = self.log.append_and_read(self.index(), batch, &mut self.read_buf);
            let tracker = self.tracker.as_mut().expect("finalized");
            for batch in self.read_buf.drain(..) {
                tracker.apply(batch.iter().cloned());
            }
            for flush in &mut self.flushers {
                flush();
            }
            self.remote_pending = false;
            self.last_flush = Instant::now();
        }
    }

    /// Steps until `done` returns true.
    ///
    /// Finalizes first: probe frontiers are only meaningful once the
    /// tracker has seeded the initial token counts. Flushes on exit so
    /// peers never wait on updates this worker is still holding.
    pub fn step_while<F: FnMut() -> bool>(&mut self, mut more: F) {
        self.finalize();
        while more() {
            if !self.step() {
                // Idle: give the OS scheduler a chance (many workers may
                // share cores, e.g. under `cargo test`).
                std::thread::yield_now();
            }
        }
        self.flush_now();
    }

    /// True iff no pointstamps remain anywhere (the dataflow is complete).
    pub fn is_complete(&self) -> bool {
        self.tracker.as_ref().map(|t| t.is_complete()).unwrap_or(false)
    }
}

impl<T: Timestamp> Drop for Worker<T> {
    fn drop(&mut self) {
        // Covers custom driving loops that exit without `step_while`.
        self.flush_now();
    }
}
