//! The per-worker progress tracker.
//!
//! The tracker folds pointstamp count updates — the atomic batches arriving
//! on the worker's per-sender progress mailboxes (see
//! [`super::exchange::Progcaster`]) — into per-input-port frontier
//! antichains. It is *projection based*:
//! reachability (computed once, [`super::reachability`]) gives the minimal
//! path summaries from every location to every target port; each location
//! keeps a [`MutableAntichain`] of its pointstamp counts, and when a
//! location's frontier changes the diffs are projected through each summary
//! into the affected ports' frontier antichains. There is no runtime
//! fixpoint, and — the paper's central point — no operator is involved:
//! frontiers propagate through idle dataflow fragments without scheduling a
//! single operator (§5.2, §7.3).
//!
//! The fold path is **allocation-free in the steady state**: the
//! per-location count antichains store their entries in flat sorted runs
//! (no tree nodes — see [`super::antichain`]), and every piece of scratch
//! this module needs (`staged` per-location batches, `projected` per-port
//! diffs, the dirty-node queue) is drained in place rather than consumed,
//! so its capacity is reused across `apply` calls. After warm-up, folding
//! an inbound progress batch touches no allocator at all — proven by the
//! counting-allocator test in `rust/tests/alloc_steady_state.rs`.

use super::antichain::{Antichain, MutableAntichain};
use super::location::Location;
use super::reachability::{GraphTopology, Summaries};
use super::timestamp::{PathSummary, Timestamp};
use std::cell::RefCell;
use std::rc::Rc;

/// A frontier shared between the tracker (which maintains it) and an
/// operator input handle (which reads it).
pub struct SharedFrontier<T: Timestamp> {
    /// The frontier itself.
    pub antichain: MutableAntichain<T>,
    /// Set by the tracker when the frontier changes; cleared by the reader.
    pub changed: bool,
}

/// Shared handle to a port frontier.
pub type FrontierHandle<T> = Rc<RefCell<SharedFrontier<T>>>;

/// The per-worker progress tracker.
pub struct Tracker<T: Timestamp> {
    summaries: Summaries<T>,
    /// Pointstamp counts per location (indexed as in `summaries.locations`).
    counts: Vec<MutableAntichain<T>>,
    /// Frontier handles per location (populated for target ports only).
    frontiers: Vec<Option<FrontierHandle<T>>>,
    /// Nodes whose input frontier changed since last drained.
    dirty_nodes: Vec<usize>,
    dirty_flag: Vec<bool>,
    /// Scratch: per-location update staging.
    staged: Vec<Vec<(T, i64)>>,
    staged_dirty: Vec<usize>,
    /// Scratch: per-target projected diffs.
    projected: Vec<Vec<(T, i64)>>,
    projected_dirty: Vec<usize>,
}

impl<T: Timestamp> Tracker<T> {
    /// Builds a tracker for `topology`, seeding every source (output) port
    /// with `peers` initial pointstamps at `T::minimum()` — one initial
    /// timestamp token per output per worker (§3.1: "each dataflow operator
    /// is initially provided with a timestamp token for each of its output
    /// edges").
    pub fn new(topology: &GraphTopology<T>, peers: usize) -> Self {
        Self::new_with(topology, peers, Vec::new())
    }

    /// Like [`Tracker::new`], but adopts externally created frontier handles
    /// for the given `(node, port)` target ports — operators receive their
    /// handles during graph construction, before the tracker exists.
    pub fn new_with(
        topology: &GraphTopology<T>,
        peers: usize,
        provided: Vec<(usize, usize, FrontierHandle<T>)>,
    ) -> Self {
        let summaries = Summaries::build(topology);
        let n_locs = summaries.locations.len();
        let n_nodes = topology.nodes.len();
        let mut frontiers: Vec<Option<FrontierHandle<T>>> = vec![None; n_locs];
        for (node, port, handle) in provided {
            let idx = summaries.index[&Location::target(node, port)];
            frontiers[idx] = Some(handle);
        }
        for &t in &summaries.targets {
            if frontiers[t].is_none() {
                frontiers[t] = Some(Rc::new(RefCell::new(SharedFrontier {
                    antichain: MutableAntichain::new(),
                    changed: false,
                })));
            }
        }
        let mut tracker = Tracker {
            counts: (0..n_locs).map(|_| MutableAntichain::new()).collect(),
            frontiers,
            dirty_nodes: Vec::new(),
            dirty_flag: vec![false; n_nodes],
            staged: vec![Vec::new(); n_locs],
            staged_dirty: Vec::new(),
            projected: vec![Vec::new(); n_locs],
            projected_dirty: Vec::new(),
            summaries,
        };
        // Seed initial tokens: one per output port per worker.
        let seed: Vec<((Location, T), i64)> = tracker
            .summaries
            .locations
            .iter()
            .filter(|l| l.is_source())
            .map(|&l| ((l, T::minimum()), peers as i64))
            .collect();
        tracker.apply(seed.iter().cloned());
        tracker
    }

    /// The frontier handle for input port `port` of node `node`.
    ///
    /// The same handle is shared with the operator's input; the tracker
    /// updates it in place and sets its `changed` flag.
    pub fn frontier_handle(&self, node: usize, port: usize) -> FrontierHandle<T> {
        let idx = self.summaries.index[&Location::target(node, port)];
        self.frontiers[idx]
            .as_ref()
            .expect("target port has a frontier")
            .clone()
    }

    /// Applies one sender's atomic batch of pointstamp updates.
    ///
    /// The worker calls this once per batch drained from its progress
    /// mailboxes, preserving each sender's FIFO order; batches from
    /// different senders may be applied in any interleaving (any subset of
    /// atomic updates is a conservative view — §4). Convenience wrapper
    /// over [`Tracker::apply`] for the shared-`Arc` batches the mailboxes
    /// carry.
    pub fn apply_batch(&mut self, batch: &[((Location, T), i64)]) {
        self.apply(batch.iter().cloned());
    }

    /// Applies a batch of pointstamp updates atomically.
    ///
    /// All count changes for a location are applied in one step (so paired
    /// `-old/+new` downgrades can never transiently release a frontier), and
    /// all projected diffs for a port are applied in one step (so paired
    /// `consume/retain` actions can never transiently advance a downstream
    /// frontier). Counts may accumulate negative between batches (a
    /// consume heard before its produce, legitimate under decentralized
    /// exchange); see [`super::antichain::MutableAntichain::update_iter`].
    pub fn apply<I>(&mut self, updates: I)
    where
        I: IntoIterator<Item = ((Location, T), i64)>,
    {
        // Stage updates per location.
        for ((loc, t), diff) in updates {
            let idx = self.summaries.index[&loc];
            if self.staged[idx].is_empty() {
                self.staged_dirty.push(idx);
            }
            self.staged[idx].push((t, diff));
        }
        // Per location: fold into counts, project frontier diffs. The
        // staged vectors are drained (not consumed) so their capacity is
        // reused across applies — this path runs on every progress batch.
        for si in 0..self.staged_dirty.len() {
            let lidx = self.staged_dirty[si];
            let mut batch = std::mem::take(&mut self.staged[lidx]);
            for (t, diff) in self.counts[lidx].update_iter(batch.drain(..)) {
                for (tgt, summaries) in &self.summaries.forward[lidx] {
                    for s in summaries {
                        if let Some(projected_t) = s.results_in(&t) {
                            if self.projected[*tgt].is_empty() {
                                self.projected_dirty.push(*tgt);
                            }
                            self.projected[*tgt].push((projected_t, diff));
                        }
                    }
                }
            }
            self.staged[lidx] = batch;
        }
        self.staged_dirty.clear();
        // Per target port: fold projected diffs into the shared frontier.
        for pi in 0..self.projected_dirty.len() {
            let tgt = self.projected_dirty[pi];
            let mut batch = std::mem::take(&mut self.projected[tgt]);
            let handle = self.frontiers[tgt].as_ref().expect("target frontier");
            let mut shared = handle.borrow_mut();
            let changed = shared.antichain.update_iter(batch.drain(..)).count() > 0;
            if changed {
                shared.changed = true;
                let node = self.summaries.locations[tgt].node;
                if !self.dirty_flag[node] {
                    self.dirty_flag[node] = true;
                    self.dirty_nodes.push(node);
                }
            }
            self.projected[tgt] = batch;
        }
        self.projected_dirty.clear();
    }

    /// Drains the set of nodes whose input frontiers changed since the last
    /// call (the worker uses this to schedule frontier-interested operators).
    pub fn drain_dirty_nodes(&mut self, into: &mut Vec<usize>) {
        for &n in &self.dirty_nodes {
            self.dirty_flag[n] = false;
        }
        into.extend(self.dirty_nodes.drain(..));
    }

    /// True iff no location holds any outstanding pointstamp — the dataflow
    /// is complete.
    pub fn is_complete(&self) -> bool {
        self.counts.iter().all(|c| c.is_empty())
    }

    /// The least timestamp any outstanding pointstamp (token or in-flight
    /// message, anywhere in this worker's view of the cluster) still holds;
    /// `None` once the dataflow is complete.
    ///
    /// This is the *global frontier bound* the checkpoint coordinator seals
    /// against: every message with a timestamp strictly below the bound has
    /// been both produced **and** consumed (pointstamp accounting counts
    /// both), so operator state restricted to epochs below the bound is
    /// immutable — a globally consistent cut obtained for free from the
    /// progress plane (no barrier protocol). The view is conservative: it
    /// may lag the true global frontier, never lead it.
    pub fn min_frontier(&self) -> Option<&T> {
        self.counts
            .iter()
            .flat_map(|c| c.frontier().iter())
            .min()
    }

    /// The current frontier at a *source* location (used by probes on
    /// outputs and by diagnostics).
    pub fn source_counts(&self, node: usize, port: usize) -> &MutableAntichain<T> {
        let idx = self.summaries.index[&Location::source(node, port)];
        &self.counts[idx]
    }

    /// Recomputes the frontier of `(node, port)` from scratch, from the raw
    /// counts — an oracle used by the property-test suite to validate the
    /// incremental projection machinery.
    pub fn naive_target_frontier(&self, node: usize, port: usize) -> Antichain<T> {
        let want = Location::target(node, port);
        let mut result = Antichain::new();
        for (lidx, counts) in self.counts.iter().enumerate() {
            for (tgt, summaries) in &self.summaries.forward[lidx] {
                if self.summaries.locations[*tgt] == want {
                    for t in counts.frontier() {
                        for s in summaries {
                            if let Some(projected) = s.results_in(t) {
                                result.insert(projected);
                            }
                        }
                    }
                }
            }
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::progress::reachability::NodeTopology;

    /// input(0) -> op(1) -> probe(2)
    fn linear() -> GraphTopology<u64> {
        let mut g = GraphTopology::default();
        g.nodes.push(NodeTopology::identity("input", 0, 1));
        g.nodes.push(NodeTopology::identity("op", 1, 1));
        g.nodes.push(NodeTopology::identity("probe", 1, 0));
        g.edges.push((Location::source(0, 0), Location::target(1, 0)));
        g.edges.push((Location::source(1, 0), Location::target(2, 0)));
        g
    }

    #[test]
    fn initial_frontiers_at_minimum() {
        let tracker = Tracker::new(&linear(), 1);
        let f1 = tracker.frontier_handle(1, 0);
        assert_eq!(f1.borrow().antichain.frontier(), &[0]);
        let f2 = tracker.frontier_handle(2, 0);
        assert_eq!(f2.borrow().antichain.frontier(), &[0]);
    }

    #[test]
    fn downgrade_advances_downstream_frontier() {
        let mut tracker = Tracker::new(&linear(), 1);
        // The input's token moves 0 -> 5; op's token is dropped.
        tracker.apply(vec![
            ((Location::source(0, 0), 5u64), 1),
            ((Location::source(0, 0), 0u64), -1),
            ((Location::source(1, 0), 0u64), -1),
        ]);
        let f1 = tracker.frontier_handle(1, 0);
        assert_eq!(f1.borrow().antichain.frontier(), &[5]);
        let f2 = tracker.frontier_handle(2, 0);
        assert_eq!(f2.borrow().antichain.frontier(), &[5]);
    }

    #[test]
    fn op_token_holds_downstream_but_not_own_input() {
        let mut tracker = Tracker::new(&linear(), 1);
        // Input advances to 10, op still holds its token at 0.
        tracker.apply(vec![
            ((Location::source(0, 0), 10u64), 1),
            ((Location::source(0, 0), 0u64), -1),
        ]);
        // Op's own input frontier advances (its token is at its OUTPUT)...
        let f1 = tracker.frontier_handle(1, 0);
        assert_eq!(f1.borrow().antichain.frontier(), &[10]);
        // ...but the probe's frontier is held at 0 by the op's token.
        let f2 = tracker.frontier_handle(2, 0);
        assert_eq!(f2.borrow().antichain.frontier(), &[0]);
    }

    #[test]
    fn messages_hold_frontier_until_consumed() {
        let mut tracker = Tracker::new(&linear(), 1);
        // Drop all initial tokens but leave a message at op's input at 3.
        tracker.apply(vec![
            ((Location::target(1, 0), 3u64), 1),
            ((Location::source(0, 0), 0u64), -1),
            ((Location::source(1, 0), 0u64), -1),
        ]);
        let f1 = tracker.frontier_handle(1, 0);
        assert_eq!(f1.borrow().antichain.frontier(), &[3]);
        let f2 = tracker.frontier_handle(2, 0);
        // The message could still cause output at 3.
        assert_eq!(f2.borrow().antichain.frontier(), &[3]);
        // Consuming it completes the dataflow.
        tracker.apply(vec![((Location::target(1, 0), 3u64), -1)]);
        assert!(f1.borrow().antichain.is_empty());
        assert!(f2.borrow().antichain.is_empty());
        assert!(tracker.is_complete());
    }

    #[test]
    fn atomic_downgrade_produces_single_transition() {
        let mut tracker = Tracker::new(&linear(), 1);
        tracker.apply(vec![((Location::source(1, 0), 0u64), -1)]);
        let f2 = tracker.frontier_handle(2, 0);
        f2.borrow_mut().changed = false;
        // -old/+new in one atomic batch: frontier goes 0 -> 7 exactly.
        tracker.apply(vec![
            ((Location::source(0, 0), 7u64), 1),
            ((Location::source(0, 0), 0u64), -1),
        ]);
        assert!(f2.borrow().changed);
        assert_eq!(f2.borrow().antichain.frontier(), &[7]);
    }

    #[test]
    fn dirty_nodes_reported_once() {
        let mut tracker = Tracker::new(&linear(), 1);
        tracker.apply(vec![
            ((Location::source(0, 0), 2u64), 1),
            ((Location::source(0, 0), 0u64), -1),
        ]);
        let mut dirty = Vec::new();
        tracker.drain_dirty_nodes(&mut dirty);
        // Node 1 and node 2 changed (in some order), node 0 has no inputs.
        dirty.sort();
        assert_eq!(dirty, vec![1, 2]);
        let mut again = Vec::new();
        tracker.drain_dirty_nodes(&mut again);
        assert!(again.is_empty());
    }

    #[test]
    fn multi_worker_seed_counts() {
        let mut tracker = Tracker::new(&linear(), 3);
        // One worker dropping its token does not advance the frontier...
        tracker.apply(vec![((Location::source(0, 0), 0u64), -1)]);
        let f1 = tracker.frontier_handle(1, 0);
        assert_eq!(f1.borrow().antichain.frontier(), &[0]);
        // ...all three do.
        tracker.apply(vec![
            ((Location::source(0, 0), 0u64), -1),
            ((Location::source(0, 0), 0u64), -1),
        ]);
        assert!(f1.borrow().antichain.is_empty());
    }

    #[test]
    fn min_frontier_tracks_least_outstanding_pointstamp() {
        let mut tracker = Tracker::new(&linear(), 1);
        assert_eq!(tracker.min_frontier(), Some(&0));
        // Input advances to 6; op still holds its token at 0.
        tracker.apply(vec![
            ((Location::source(0, 0), 6u64), 1),
            ((Location::source(0, 0), 0u64), -1),
        ]);
        assert_eq!(tracker.min_frontier(), Some(&0));
        // Op's token moves to 4: the global bound follows the minimum.
        tracker.apply(vec![
            ((Location::source(1, 0), 4u64), 1),
            ((Location::source(1, 0), 0u64), -1),
        ]);
        assert_eq!(tracker.min_frontier(), Some(&4));
        // An in-flight message below every token holds the bound down.
        tracker.apply(vec![((Location::target(1, 0), 2u64), 1)]);
        assert_eq!(tracker.min_frontier(), Some(&2));
        tracker.apply(vec![
            ((Location::target(1, 0), 2u64), -1),
            ((Location::source(0, 0), 6u64), -1),
            ((Location::source(1, 0), 4u64), -1),
        ]);
        assert_eq!(tracker.min_frontier(), None);
        assert!(tracker.is_complete());
    }

    #[test]
    fn incremental_matches_naive_oracle() {
        let mut tracker = Tracker::new(&linear(), 2);
        let steps: Vec<Vec<((Location, u64), i64)>> = vec![
            vec![((Location::source(0, 0), 4), 1), ((Location::source(0, 0), 0), -1)],
            vec![((Location::target(1, 0), 4), 1)],
            vec![((Location::source(0, 0), 9), 1), ((Location::source(0, 0), 4), -1)],
            vec![((Location::target(1, 0), 4), -1), ((Location::source(1, 0), 4), 1)],
            vec![((Location::source(1, 0), 0), -2)],
            vec![((Location::source(1, 0), 4), -1)],
        ];
        for step in steps {
            tracker.apply(step);
            for (node, port) in [(1, 0), (2, 0)] {
                let handle = tracker.frontier_handle(node, port);
                let mut got = handle.borrow().antichain.to_antichain();
                got.sort();
                let mut want = tracker.naive_target_frontier(node, port);
                want.sort();
                assert_eq!(got, want, "node {node} port {port}");
            }
        }
    }
}
