//! Timestamps, partial orders, and path summaries.
//!
//! Timestamps in this engine may be partially ordered (§5.1: "timestamps in
//! timely dataflow can be multidimensional and result in frontiers defined by
//! multiple minima"). The engine is generic over any [`Timestamp`]; the
//! evaluation workloads use `u64` nanoseconds, and [`Product`] provides the
//! multidimensional case exercised by the test suite.

use std::fmt::Debug;
use std::hash::Hash;

/// A partial order. `less_equal` need not relate all pairs of elements.
///
/// This is deliberately separate from `Ord`: `Timestamp` also requires a
/// *total* order (`Ord`) for use in ordered containers (e.g. the `BTreeMap`
/// of the paper's Figure 5), which for partially ordered types like
/// [`Product`] is an arbitrary linear extension (lexicographic).
pub trait PartialOrder: PartialEq {
    /// Returns true iff `self` is less than or equal to `other` in the
    /// partial order.
    fn less_equal(&self, other: &Self) -> bool;
    /// Returns true iff `self` is strictly less than `other`.
    fn less_than(&self, other: &Self) -> bool {
        self.less_equal(other) && self != other
    }
}

/// A summary of the minimal effect a path through the dataflow graph has on
/// a timestamp that traverses it.
///
/// Summaries compose (`followed_by`) and act on timestamps (`results_in`);
/// both return `None` on overflow, which progress tracking treats as "this
/// path can never produce a timestamp" (a conservative fiction that is safe
/// because larger timestamps impose weaker constraints).
pub trait PathSummary<T>: Clone + Eq + PartialOrder + Debug + Hash + Send + 'static {
    /// The timestamp that results from a timestamp `src` crossing this path.
    fn results_in(&self, src: &T) -> Option<T>;
    /// The summary of this path followed by `other`.
    fn followed_by(&self, other: &Self) -> Option<Self>;
}

/// A logical timestamp.
///
/// `Ord` is a total order used only for containers and canonicalization; the
/// semantically meaningful order is [`PartialOrder`]. `Summary::default()`
/// must be the identity ("no advancement") summary.
///
/// The [`Wire`](crate::net::Wire) bound lets progress batches (and message
/// timestamps) cross process boundaries: the decentralized progress plane
/// serializes `((Location, T), i64)` batches onto the net fabric whenever
/// a peer worker lives in another process, so every timestamp type must be
/// encodable (the codec covers the unsigned integers, `()`, and
/// [`Product`]).
pub trait Timestamp:
    Clone + Eq + Ord + PartialOrder + Debug + Hash + Send + Sync + crate::net::Wire + 'static
{
    /// Path summaries for this timestamp type.
    type Summary: PathSummary<Self> + Default;
    /// The least timestamp; initial timestamp tokens carry this (§3.1's
    /// "minimal zero timestamp").
    fn minimum() -> Self;
}

// ---------------------------------------------------------------------------
// Total orders: unsigned integers (nanosecond timestamps in the evaluation).
// ---------------------------------------------------------------------------

macro_rules! impl_uint_timestamp {
    ($t:ty) => {
        impl PartialOrder for $t {
            #[inline]
            fn less_equal(&self, other: &Self) -> bool {
                self <= other
            }
            #[inline]
            fn less_than(&self, other: &Self) -> bool {
                self < other
            }
        }
        // The summary for an integer timestamp is an integer increment.
        impl PathSummary<$t> for $t {
            #[inline]
            fn results_in(&self, src: &$t) -> Option<$t> {
                self.checked_add(*src)
            }
            #[inline]
            fn followed_by(&self, other: &Self) -> Option<Self> {
                self.checked_add(*other)
            }
        }
        impl Timestamp for $t {
            type Summary = $t;
            #[inline]
            fn minimum() -> Self {
                0
            }
        }
    };
}

impl_uint_timestamp!(u8);
impl_uint_timestamp!(u16);
impl_uint_timestamp!(u32);
impl_uint_timestamp!(u64);
impl_uint_timestamp!(usize);

// ---------------------------------------------------------------------------
// The trivial timestamp: a dataflow with a single logical batch.
// ---------------------------------------------------------------------------

impl PartialOrder for () {
    #[inline]
    fn less_equal(&self, _other: &Self) -> bool {
        true
    }
}
impl PathSummary<()> for () {
    #[inline]
    fn results_in(&self, _src: &()) -> Option<()> {
        Some(())
    }
    #[inline]
    fn followed_by(&self, _other: &Self) -> Option<Self> {
        Some(())
    }
}
impl Timestamp for () {
    type Summary = ();
    #[inline]
    fn minimum() -> Self {}
}

// ---------------------------------------------------------------------------
// Product: partially ordered pairs (multidimensional timestamps).
// ---------------------------------------------------------------------------

/// A pair of timestamps ordered *componentwise* — the classic partially
/// ordered product timestamp of Naiad / Timely Dataflow.
///
/// `(a1, b1) ≤ (a2, b2)` iff `a1 ≤ a2` and `b1 ≤ b2`. The derived `Ord` is a
/// lexicographic linear extension used only by ordered containers.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Product<A, B> {
    /// The outer component.
    pub outer: A,
    /// The inner component.
    pub inner: B,
}

impl<A, B> Product<A, B> {
    /// Creates a new product timestamp from its components.
    pub fn new(outer: A, inner: B) -> Self {
        Product { outer, inner }
    }
}

impl<A: Debug, B: Debug> Debug for Product<A, B> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        write!(f, "({:?}, {:?})", self.outer, self.inner)
    }
}

impl<A: PartialOrder, B: PartialOrder> PartialOrder for Product<A, B> {
    #[inline]
    fn less_equal(&self, other: &Self) -> bool {
        self.outer.less_equal(&other.outer) && self.inner.less_equal(&other.inner)
    }
}

impl<A: Timestamp, B: Timestamp> PathSummary<Product<A, B>>
    for Product<A::Summary, B::Summary>
{
    #[inline]
    fn results_in(&self, src: &Product<A, B>) -> Option<Product<A, B>> {
        Some(Product::new(
            self.outer.results_in(&src.outer)?,
            self.inner.results_in(&src.inner)?,
        ))
    }
    #[inline]
    fn followed_by(&self, other: &Self) -> Option<Self> {
        Some(Product::new(
            self.outer.followed_by(&other.outer)?,
            self.inner.followed_by(&other.inner)?,
        ))
    }
}

impl<A: Timestamp, B: Timestamp> Timestamp for Product<A, B> {
    type Summary = Product<A::Summary, B::Summary>;
    #[inline]
    fn minimum() -> Self {
        Product::new(A::minimum(), B::minimum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uint_partial_order_is_total() {
        assert!(3u64.less_equal(&3));
        assert!(3u64.less_than(&4));
        assert!(!4u64.less_than(&4));
        assert!(!4u64.less_equal(&3));
    }

    #[test]
    fn uint_summary_acts_by_addition() {
        let s: u64 = 5;
        assert_eq!(s.results_in(&10), Some(15));
        assert_eq!(s.followed_by(&7), Some(12));
        assert_eq!(u64::MAX.results_in(&1), None);
    }

    #[test]
    fn uint_summary_default_is_identity() {
        let s = <u64 as Timestamp>::Summary::default();
        assert_eq!(s.results_in(&42), Some(42));
    }

    #[test]
    fn product_is_partially_ordered() {
        let a = Product::new(1u64, 2u64);
        let b = Product::new(2u64, 1u64);
        assert!(!a.less_equal(&b));
        assert!(!b.less_equal(&a));
        assert!(a.less_equal(&Product::new(1, 2)));
        assert!(a.less_equal(&Product::new(2, 2)));
        assert!(Product::<u64, u64>::minimum().less_equal(&a));
    }

    #[test]
    fn product_summary_composes_componentwise() {
        let s = Product::new(1u64, 0u64);
        let t = Product::new(0u64, 3u64);
        // `followed_by` is ambiguous without naming the timestamp type the
        // summary acts on (u64 summaries serve any uint timestamp).
        let composed =
            <Product<u64, u64> as PathSummary<Product<u64, u64>>>::followed_by(&s, &t);
        assert_eq!(composed, Some(Product::new(1, 3)));
        assert_eq!(
            s.results_in(&Product::new(10u64, 20u64)),
            Some(Product::new(11, 20))
        );
    }

    #[test]
    fn unit_timestamp_is_trivial() {
        assert!(().less_equal(&()));
        assert_eq!(<() as Timestamp>::minimum(), ());
        assert_eq!(().results_in(&()), Some(()));
    }
}
