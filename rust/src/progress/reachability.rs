//! Path-summary reachability over the dataflow graph.
//!
//! Progress tracking needs, for every location `l` and every operator input
//! (target) port `p`, the set of *minimal path summaries* from `l` to `p`:
//! if a pointstamp `(l, t)` is outstanding, then `p` may yet observe any
//! timestamp `≥ s.results_in(t)` for a summary `s` of a path `l → p`.
//!
//! The closure is computed once at dataflow construction (no runtime
//! fixpoint): a worklist propagates summaries backwards across channel edges
//! (identity summaries) and operator-internal connections (declared
//! summaries; the feedback operator declares a strictly advancing one, which
//! is what makes cyclic dataflows — supported here, unlike Spark/Flink —
//! terminate).

use super::antichain::Antichain;
use super::location::Location;
use super::timestamp::{PartialOrder, PathSummary, Timestamp};
use std::collections::HashMap;

/// Static description of one node (operator) in the dataflow graph.
#[derive(Clone, Debug)]
pub struct NodeTopology<T: Timestamp> {
    /// Operator name, for diagnostics.
    pub name: String,
    /// Number of input (target) ports.
    pub inputs: usize,
    /// Number of output (source) ports.
    pub outputs: usize,
    /// `internal[i][o]`: minimal summaries from input port `i` to output
    /// port `o`. An empty antichain means "input `i` can never cause output
    /// on `o`".
    pub internal: Vec<Vec<Antichain<T::Summary>>>,
}

impl<T: Timestamp> NodeTopology<T> {
    /// A node whose every input connects to every output with the identity
    /// summary — the default for ordinary operators, which may produce
    /// output at the timestamp of any input they receive.
    pub fn identity(name: &str, inputs: usize, outputs: usize) -> Self {
        let internal = (0..inputs)
            .map(|_| {
                (0..outputs)
                    .map(|_| Antichain::from_elem(T::Summary::default()))
                    .collect()
            })
            .collect();
        NodeTopology { name: name.to_string(), inputs, outputs, internal }
    }
}

/// Static description of the dataflow graph, sufficient for reachability.
#[derive(Clone, Debug)]
pub struct GraphTopology<T: Timestamp> {
    /// Per-node port counts and internal summaries.
    pub nodes: Vec<NodeTopology<T>>,
    /// Channels: each connects a source (output) port to a target (input)
    /// port, with the identity summary.
    pub edges: Vec<(Location, Location)>,
}

impl<T: Timestamp> Default for GraphTopology<T> {
    fn default() -> Self {
        GraphTopology { nodes: Vec::new(), edges: Vec::new() }
    }
}

impl<T: Timestamp> GraphTopology<T> {
    /// All locations (every port of every node), in a canonical order.
    pub fn locations(&self) -> Vec<Location> {
        let mut locs = Vec::new();
        for (n, node) in self.nodes.iter().enumerate() {
            for i in 0..node.inputs {
                locs.push(Location::target(n, i));
            }
            for o in 0..node.outputs {
                locs.push(Location::source(n, o));
            }
        }
        locs
    }

    /// Panics if the graph contains a cycle that does not pass through a
    /// strictly advancing internal summary (such a cycle would let progress
    /// tracking livelock / the closure be unsound).
    pub fn validate_cycles(&self) {
        // Build adjacency over locations, *excluding* strictly advancing
        // internal connections, and look for a cycle (DFS colors).
        let locs = self.locations();
        let index: HashMap<Location, usize> = locs.iter().cloned().enumerate().map(|(i, l)| (l, i)).collect();
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); locs.len()];
        for (src, tgt) in &self.edges {
            adj[index[src]].push(index[tgt]);
        }
        let default = T::Summary::default();
        for (n, node) in self.nodes.iter().enumerate() {
            for i in 0..node.inputs {
                for o in 0..node.outputs {
                    let summaries = &node.internal[i][o];
                    // Non-strict iff some summary does not strictly advance.
                    let non_strict = summaries
                        .elements()
                        .iter()
                        .any(|s| s.less_equal(&default));
                    if !summaries.is_empty() && non_strict {
                        adj[index[&Location::target(n, i)]].push(index[&Location::source(n, o)]);
                    }
                }
            }
        }
        // Iterative DFS cycle detection.
        let mut color = vec![0u8; locs.len()]; // 0 white, 1 gray, 2 black
        for start in 0..locs.len() {
            if color[start] != 0 {
                continue;
            }
            let mut stack = vec![(start, 0usize)];
            color[start] = 1;
            while let Some(&mut (u, ref mut next)) = stack.last_mut() {
                if *next < adj[u].len() {
                    let v = adj[u][*next];
                    *next += 1;
                    if color[v] == 0 {
                        color[v] = 1;
                        stack.push((v, 0));
                    } else if color[v] == 1 {
                        panic!(
                            "dataflow graph contains a cycle without a strictly \
                             advancing summary (through {:?}); cycles must go \
                             through `feedback`",
                            locs[v]
                        );
                    }
                } else {
                    color[u] = 2;
                    stack.pop();
                }
            }
        }
    }
}

/// The reachability closure: minimal path summaries from every location to
/// every *target* location.
pub struct Summaries<T: Timestamp> {
    /// Canonical location order (targets and sources interleaved per node).
    pub locations: Vec<Location>,
    /// `index[loc]` = position in `locations`.
    pub index: HashMap<Location, usize>,
    /// `targets[k]` = location indices that are target ports.
    pub targets: Vec<usize>,
    /// `forward[l]` = list of `(target location index, minimal summaries)`
    /// for targets reachable from location `l`.
    pub forward: Vec<Vec<(usize, Vec<T::Summary>)>>,
}

impl<T: Timestamp> Summaries<T> {
    /// Computes the closure for `topology`. Panics on invalid cycles.
    pub fn build(topology: &GraphTopology<T>) -> Self {
        topology.validate_cycles();

        let locations = topology.locations();
        let index: HashMap<Location, usize> =
            locations.iter().cloned().enumerate().map(|(i, l)| (l, i)).collect();
        let targets: Vec<usize> = locations
            .iter()
            .enumerate()
            .filter(|(_, l)| !l.is_source())
            .map(|(i, _)| i)
            .collect();

        // Reverse adjacency: for each location `b`, the predecessors `a`
        // with the summaries of the single hop `a -> b`.
        let mut preds: Vec<Vec<(usize, T::Summary)>> = vec![Vec::new(); locations.len()];
        for (src, tgt) in &topology.edges {
            preds[index[tgt]].push((index[src], T::Summary::default()));
        }
        for (n, node) in topology.nodes.iter().enumerate() {
            for i in 0..node.inputs {
                for o in 0..node.outputs {
                    for s in node.internal[i][o].elements() {
                        preds[index[&Location::source(n, o)]]
                            .push((index[&Location::target(n, i)], s.clone()));
                    }
                }
            }
        }

        // Worklist closure: results[(l, p)] = antichain of summaries l -> p.
        let mut results: HashMap<(usize, usize), Antichain<T::Summary>> = HashMap::new();
        let mut worklist: Vec<(usize, usize)> = Vec::new();
        for &p in &targets {
            results
                .entry((p, p))
                .or_insert_with(Antichain::new)
                .insert(T::Summary::default());
            worklist.push((p, p));
        }
        while let Some((b, p)) = worklist.pop() {
            let summaries: Vec<T::Summary> = results[&(b, p)].elements().to_vec();
            for &(a, ref hop) in &preds[b] {
                for s in &summaries {
                    if let Some(composed) = hop.followed_by(s) {
                        let entry = results.entry((a, p)).or_insert_with(Antichain::new);
                        if entry.insert(composed) {
                            worklist.push((a, p));
                        }
                    }
                }
            }
        }

        let mut forward: Vec<Vec<(usize, Vec<T::Summary>)>> = vec![Vec::new(); locations.len()];
        for ((l, p), antichain) in results {
            if !antichain.is_empty() {
                forward[l].push((p, antichain.into_vec()));
            }
        }
        // Deterministic order helps tests and debugging.
        for list in &mut forward {
            list.sort_by_key(|&(p, _)| p);
        }

        Summaries { locations, index, targets, forward }
    }

    /// The summaries from `l` to targets, as `(Location, summaries)` pairs.
    pub fn reachable_from(&self, l: Location) -> impl Iterator<Item = (Location, &[T::Summary])> {
        let idx = self.index[&l];
        self.forward[idx]
            .iter()
            .map(move |(p, s)| (self.locations[*p], s.as_slice()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Builds the linear topology `input -> a -> b` (3 nodes: node 0 is an
    /// input with 1 output, nodes 1 and 2 are unary operators).
    fn linear() -> GraphTopology<u64> {
        let mut g = GraphTopology::default();
        g.nodes.push(NodeTopology::identity("input", 0, 1));
        g.nodes.push(NodeTopology::identity("a", 1, 1));
        g.nodes.push(NodeTopology::identity("b", 1, 1));
        g.edges.push((Location::source(0, 0), Location::target(1, 0)));
        g.edges.push((Location::source(1, 0), Location::target(2, 0)));
        g
    }

    #[test]
    fn linear_reachability() {
        let s = Summaries::build(&linear());
        // The input's output reaches both target ports with identity.
        let from_input: Vec<_> = s.reachable_from(Location::source(0, 0)).collect();
        assert_eq!(from_input.len(), 2);
        for (_, summaries) in from_input {
            assert_eq!(summaries, &[0u64]);
        }
        // b's input reaches only itself.
        let from_b: Vec<_> = s.reachable_from(Location::target(2, 0)).collect();
        assert_eq!(from_b.len(), 1);
        assert_eq!(from_b[0].0, Location::target(2, 0));
    }

    #[test]
    fn disconnected_ports_unreachable() {
        let s = Summaries::build(&linear());
        // Nothing reaches the input's (nonexistent) targets; b's source
        // reaches nothing (no outgoing edge).
        assert_eq!(s.reachable_from(Location::source(2, 0)).count(), 0);
    }

    #[test]
    fn feedback_cycle_summaries() {
        // input(0) -> op(1) -> feedback(2) -> op(1): the feedback node
        // declares a +1 internal summary, so op's input sees itself at +1.
        let mut g = GraphTopology::<u64>::default();
        g.nodes.push(NodeTopology::identity("input", 0, 1));
        g.nodes.push(NodeTopology::identity("op", 2, 1));
        let mut fb = NodeTopology::identity("feedback", 1, 1);
        fb.internal[0][0] = Antichain::from_elem(1u64);
        g.nodes.push(fb);
        g.edges.push((Location::source(0, 0), Location::target(1, 0)));
        g.edges.push((Location::source(1, 0), Location::target(2, 0)));
        g.edges.push((Location::source(2, 0), Location::target(1, 1)));
        let s = Summaries::build(&g);
        // op's input port 0 reaches itself only via identity (p == p), and
        // reaches input port 1 via the cycle with summary +1.
        let from: Vec<_> = s.reachable_from(Location::target(1, 0)).collect();
        let to_self: Vec<_> =
            from.iter().filter(|(l, _)| *l == Location::target(1, 0)).collect();
        assert_eq!(to_self[0].1, &[0u64]);
        let to_loop: Vec<_> =
            from.iter().filter(|(l, _)| *l == Location::target(1, 1)).collect();
        assert_eq!(to_loop[0].1, &[1u64]);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn non_advancing_cycle_panics() {
        let mut g = GraphTopology::<u64>::default();
        g.nodes.push(NodeTopology::identity("a", 1, 1));
        g.nodes.push(NodeTopology::identity("b", 1, 1));
        g.edges.push((Location::source(0, 0), Location::target(1, 0)));
        g.edges.push((Location::source(1, 0), Location::target(0, 0)));
        Summaries::build(&g);
    }

    #[test]
    fn diamond_keeps_minimal_summaries() {
        // input -> {a, b} -> join; via a the summary is +0, via b it's +5
        // (b advances timestamps): both paths end at join's two ports.
        let mut g = GraphTopology::<u64>::default();
        g.nodes.push(NodeTopology::identity("input", 0, 1));
        g.nodes.push(NodeTopology::identity("a", 1, 1));
        let mut b = NodeTopology::identity("b", 1, 1);
        b.internal[0][0] = Antichain::from_elem(5u64);
        g.nodes.push(b);
        g.nodes.push(NodeTopology::identity("join", 2, 1));
        g.edges.push((Location::source(0, 0), Location::target(1, 0)));
        g.edges.push((Location::source(0, 0), Location::target(2, 0)));
        g.edges.push((Location::source(1, 0), Location::target(3, 0)));
        g.edges.push((Location::source(2, 0), Location::target(3, 1)));
        let s = Summaries::build(&g);
        let from_input: Vec<_> = s.reachable_from(Location::source(0, 0)).collect();
        let port0: Vec<_> = from_input
            .iter()
            .filter(|(l, _)| *l == Location::target(3, 0))
            .collect();
        assert_eq!(port0[0].1, &[0u64]);
        let port1: Vec<_> = from_input
            .iter()
            .filter(|(l, _)| *l == Location::target(3, 1))
            .collect();
        assert_eq!(port1[0].1, &[5u64]);
    }
}
