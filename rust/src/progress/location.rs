//! Pointstamp locations: where in the dataflow graph a timestamp token or an
//! in-flight message "lives".
//!
//! Following Naiad (and the paper's §3), a *pointstamp* is a pair of a
//! timestamp and a location. Locations are operator ports:
//!
//! * a **source** (output) port holds the counts of live timestamp tokens
//!   that grant the ability to send on the edges leaving that port;
//! * a **target** (input) port holds the counts of message batches that have
//!   been produced for, but not yet consumed by, that port.

/// The direction of a port: operator output (`Source`) or input (`Target`).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum Port {
    /// An operator output port (tokens / capabilities live here).
    Source(usize),
    /// An operator input port (queued messages are counted here).
    Target(usize),
}

/// A location in the dataflow graph: a port of a node.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct Location {
    /// The node (operator) index in the dataflow graph.
    pub node: usize,
    /// The port and its direction.
    pub port: Port,
}

impl Location {
    /// A source (output-port) location.
    pub fn source(node: usize, port: usize) -> Self {
        Location { node, port: Port::Source(port) }
    }

    /// A target (input-port) location.
    pub fn target(node: usize, port: usize) -> Self {
        Location { node, port: Port::Target(port) }
    }

    /// True iff this is a source (output) location.
    pub fn is_source(&self) -> bool {
        matches!(self.port, Port::Source(_))
    }

    /// The port index, disregarding direction.
    pub fn port_index(&self) -> usize {
        match self.port {
            Port::Source(p) | Port::Target(p) => p,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn location_constructors() {
        let s = Location::source(3, 1);
        assert!(s.is_source());
        assert_eq!(s.port_index(), 1);
        let t = Location::target(3, 0);
        assert!(!t.is_source());
        assert_eq!(t.node, 3);
        assert_ne!(s, t);
    }

    #[test]
    fn location_is_ordered_and_hashable() {
        use std::collections::{BTreeSet, HashSet};
        let mut b = BTreeSet::new();
        let mut h = HashSet::new();
        for node in 0..3 {
            for port in 0..2 {
                b.insert(Location::source(node, port));
                b.insert(Location::target(node, port));
                h.insert(Location::source(node, port));
                h.insert(Location::target(node, port));
            }
        }
        assert_eq!(b.len(), 12);
        assert_eq!(h.len(), 12);
    }
}
