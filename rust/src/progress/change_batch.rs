//! Compacting batches of `(element, i64)` count updates.
//!
//! `ChangeBatch` is the "shared bookkeeping data structure" of §4: timestamp
//! token methods (`clone`, `downgrade`, `drop`) and message send/consume
//! accounting all record integer changes here, and the worker drains the
//! batch *after* operator logic yields, so the drained prefix reflects
//! atomic operator actions.

use std::fmt::Debug;

/// An accumulation of `(T, i64)` updates that compacts lazily.
///
/// Updates are appended in O(1); when the buffer exceeds twice the size of
/// its last compaction it is sorted and coalesced, dropping zero-count
/// entries. This keeps the structure linear in the number of *net* changes.
#[derive(Clone)]
pub struct ChangeBatch<T: Ord> {
    updates: Vec<(T, i64)>,
    /// Number of compacted (sorted, coalesced) prefix entries.
    clean: usize,
}

impl<T: Ord + Clone + Debug> ChangeBatch<T> {
    /// Creates an empty batch.
    pub fn new() -> Self {
        ChangeBatch { updates: Vec::new(), clean: 0 }
    }

    /// Creates a batch containing a single update.
    pub fn new_from(t: T, diff: i64) -> Self {
        let mut batch = Self::new();
        batch.update(t, diff);
        batch
    }

    /// Records `diff` copies of `t`.
    #[inline]
    pub fn update(&mut self, t: T, diff: i64) {
        if diff != 0 {
            self.updates.push((t, diff));
            self.maybe_compact();
        }
    }

    /// Records all updates in `iter`.
    pub fn extend<I: IntoIterator<Item = (T, i64)>>(&mut self, iter: I) {
        for (t, diff) in iter {
            if diff != 0 {
                self.updates.push((t, diff));
            }
        }
        self.maybe_compact();
    }

    /// Drains the batch, yielding compacted net updates.
    pub fn drain(&mut self) -> std::vec::Drain<'_, (T, i64)> {
        self.compact();
        self.clean = 0;
        self.updates.drain(..)
    }

    /// Compacts and takes the accumulated net updates as an owned `Vec` in
    /// one move (no per-element copy).
    ///
    /// This is the broadcast hot path of the decentralized progress plane:
    /// the returned `Vec` becomes the shared atomic batch handed to every
    /// peer mailbox, so coalescing happens exactly once, at send time.
    /// Returns an empty `Vec` when the updates net to nothing.
    pub fn take_coalesced(&mut self) -> Vec<(T, i64)> {
        self.compact();
        self.clean = 0;
        std::mem::take(&mut self.updates)
    }

    /// Number of updates currently buffered, *without* compacting — an
    /// upper bound on the net updates. `0` means definitely empty, which
    /// makes this a cheap emptiness hint for per-step flush policies
    /// (unlike [`ChangeBatch::is_empty`], which sorts).
    #[inline]
    pub fn raw_len(&self) -> usize {
        self.updates.len()
    }

    /// Drains the batch into `other`.
    pub fn drain_into(&mut self, other: &mut ChangeBatch<T>) {
        if !self.updates.is_empty() {
            other.extend(self.drain());
        }
    }

    /// True iff the batch accumulates to no net updates.
    pub fn is_empty(&mut self) -> bool {
        self.compact();
        self.updates.is_empty()
    }

    /// Number of net updates currently held.
    pub fn len(&mut self) -> usize {
        self.compact();
        self.updates.len()
    }

    /// Immutable view of the (possibly uncompacted) updates.
    pub fn unstable_updates(&self) -> &[(T, i64)] {
        &self.updates
    }

    /// Sorts and coalesces the updates, removing zero-count entries.
    pub fn compact(&mut self) {
        if self.clean < self.updates.len() {
            // Unstable sort: in-place, no scratch allocation (equal keys
            // are summed immediately below, so stability is irrelevant) —
            // this keeps the steady-state flush path allocation-free.
            self.updates.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            let mut write = 0;
            let mut read = 0;
            while read < self.updates.len() {
                let mut sum = self.updates[read].1;
                let mut next = read + 1;
                while next < self.updates.len() && self.updates[next].0 == self.updates[read].0 {
                    sum += self.updates[next].1;
                    next += 1;
                }
                if sum != 0 {
                    self.updates.swap(write, read);
                    self.updates[write].1 = sum;
                    write += 1;
                }
                read = next;
            }
            self.updates.truncate(write);
            self.clean = self.updates.len();
        }
    }

    fn maybe_compact(&mut self) {
        if self.updates.len() > 32 && self.updates.len() > 2 * self.clean {
            self.compact();
        }
    }
}

impl<T: Ord + Clone + Debug> Default for ChangeBatch<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Ord + Debug> Debug for ChangeBatch<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        f.debug_list().entries(self.updates.iter()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalesces_and_drops_zeros() {
        let mut b = ChangeBatch::new();
        b.update(3u64, 1);
        b.update(3u64, -1);
        b.update(5u64, 2);
        b.update(5u64, 3);
        let drained: Vec<_> = b.drain().collect();
        assert_eq!(drained, vec![(5, 5)]);
    }

    #[test]
    fn zero_updates_ignored() {
        let mut b = ChangeBatch::new();
        b.update(1u64, 0);
        assert!(b.is_empty());
    }

    #[test]
    fn take_coalesced_moves_net_updates() {
        let mut b = ChangeBatch::new();
        b.update(3u64, 1);
        b.update(3u64, -1);
        b.update(7u64, 2);
        assert!(b.raw_len() >= 1);
        let taken = b.take_coalesced();
        assert_eq!(taken, vec![(7, 2)]);
        assert_eq!(b.raw_len(), 0);
        // The batch is reusable after the take.
        b.update(1u64, 1);
        assert_eq!(b.take_coalesced(), vec![(1, 1)]);
        // A fully canceling batch takes to empty.
        b.update(5u64, 4);
        b.update(5u64, -4);
        assert!(b.take_coalesced().is_empty());
    }

    #[test]
    fn drain_into_accumulates() {
        let mut a = ChangeBatch::new_from(1u64, 2);
        let mut b = ChangeBatch::new_from(1u64, -2);
        a.drain_into(&mut b);
        assert!(b.is_empty());
    }

    #[test]
    fn matches_naive_hashmap_accumulation() {
        // Seeded randomized equivalence with a HashMap accumulator.
        let mut state = 0x2545f4914f6cdd1du64;
        let mut rng = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let mut batch = ChangeBatch::new();
        let mut naive = std::collections::HashMap::new();
        for _ in 0..10_000 {
            let t = (rng() % 50) as u64;
            let diff = (rng() % 7) as i64 - 3;
            batch.update(t, diff);
            *naive.entry(t).or_insert(0i64) += diff;
        }
        let mut got: Vec<_> = batch.drain().collect();
        got.sort();
        let mut want: Vec<_> = naive.into_iter().filter(|&(_, d)| d != 0).collect();
        want.sort();
        assert_eq!(got, want);
    }
}
