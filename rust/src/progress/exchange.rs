//! The sequenced progress log: how workers share pointstamp updates.
//!
//! Following Naiad's progress protocol (paper §4: "these collected changes
//! are broadcast among unsynchronized workers. Any subset of atomic updates
//! forms a conservative view of the coordination state"), each worker
//! appends *atomic batches* of `((Location, T), i64)` updates to a shared,
//! totally ordered log, and every worker applies the log in order.
//!
//! The total order makes prefix-safety immediate: a `-1` (message consumed,
//! token dropped) can only be appended after the action it reflects, which
//! happens after the corresponding `+1` batch was appended (workers append
//! their produce counts *before* handing messages to the data fabric), so
//! every prefix of the log over-approximates the outstanding pointstamps.
//!
//! The log self-compacts: batches ack'd by every worker are dropped.

use super::location::Location;
use super::timestamp::Timestamp;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// One atomic batch of pointstamp updates from one worker.
pub type ProgressBatch<T> = Vec<((Location, T), i64)>;

struct LogInner<T> {
    /// Batches not yet read by every worker; `base` is the global sequence
    /// number of `batches[0]`.
    batches: VecDeque<Arc<ProgressBatch<T>>>,
    base: usize,
    /// Per-worker read cursors (global sequence numbers).
    cursors: Vec<usize>,
}

/// A shared, totally ordered log of atomic progress batches.
pub struct ProgressLog<T> {
    inner: Mutex<LogInner<T>>,
    /// Total batches ever appended — lets readers skip the lock entirely
    /// when they are already caught up (the hot-loop fast path).
    tail: AtomicUsize,
}

impl<T: Timestamp> ProgressLog<T> {
    /// Creates a log shared by `peers` workers.
    pub fn new(peers: usize) -> Arc<Self> {
        Arc::new(ProgressLog {
            inner: Mutex::new(LogInner {
                batches: VecDeque::new(),
                base: 0,
                cursors: vec![0; peers],
            }),
            tail: AtomicUsize::new(0),
        })
    }

    /// Appends an atomic batch (no-op if empty).
    pub fn append(&self, batch: ProgressBatch<T>) {
        if batch.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.batches.push_back(Arc::new(batch));
        self.tail.store(inner.base + inner.batches.len(), Ordering::Release);
    }

    /// The global sequence number of the next batch to be appended.
    #[inline]
    pub fn tail(&self) -> usize {
        self.tail.load(Ordering::Acquire)
    }

    /// Appends a batch and reads everything new for `worker` in one
    /// critical section (the common per-step call). Returns the worker's
    /// new cursor; a caller holding that cursor can skip the next call
    /// entirely while `tail()` has not moved and it has nothing to append.
    pub fn append_and_read(
        &self,
        worker: usize,
        batch: ProgressBatch<T>,
        read_into: &mut Vec<Arc<ProgressBatch<T>>>,
    ) -> usize {
        let mut inner = self.inner.lock().unwrap();
        if !batch.is_empty() {
            inner.batches.push_back(Arc::new(batch));
            self.tail.store(inner.base + inner.batches.len(), Ordering::Release);
        }
        let base = inner.base;
        let cursor = inner.cursors[worker];
        let start = cursor.saturating_sub(base);
        for i in start..inner.batches.len() {
            read_into.push(inner.batches[i].clone());
        }
        let new_cursor = base + inner.batches.len();
        inner.cursors[worker] = new_cursor;
        // Compact: drop batches read by all workers.
        let min_cursor = *inner.cursors.iter().min().unwrap();
        while inner.base < min_cursor {
            inner.batches.pop_front();
            inner.base += 1;
        }
        new_cursor
    }

    /// Reads all batches `worker` has not yet seen.
    pub fn read(&self, worker: usize, read_into: &mut Vec<Arc<ProgressBatch<T>>>) {
        self.append_and_read(worker, Vec::new(), read_into);
    }

    /// Number of unread batches pending for `worker` (for idle detection).
    pub fn pending(&self, worker: usize) -> usize {
        let inner = self.inner.lock().unwrap();
        (inner.base + inner.batches.len()).saturating_sub(inner.cursors[worker])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn update(n: usize, t: u64, d: i64) -> ((Location, u64), i64) {
        ((Location::source(n, 0), t), d)
    }

    #[test]
    fn all_workers_see_all_batches_in_order() {
        let log = ProgressLog::<u64>::new(2);
        log.append(vec![update(0, 1, 1)]);
        log.append(vec![update(1, 2, 1)]);

        let mut got0 = Vec::new();
        log.read(0, &mut got0);
        assert_eq!(got0.len(), 2);
        assert_eq!(got0[0][0], update(0, 1, 1));
        assert_eq!(got0[1][0], update(1, 2, 1));

        // Worker 0 re-reading sees nothing new.
        let mut again = Vec::new();
        log.read(0, &mut again);
        assert!(again.is_empty());

        // Worker 1 still sees both.
        let mut got1 = Vec::new();
        log.read(1, &mut got1);
        assert_eq!(got1.len(), 2);
    }

    #[test]
    fn compaction_drops_fully_read_prefix() {
        let log = ProgressLog::<u64>::new(2);
        for i in 0..10 {
            log.append(vec![update(0, i, 1)]);
        }
        let mut sink = Vec::new();
        log.read(0, &mut sink);
        assert_eq!(log.inner.lock().unwrap().batches.len(), 10);
        sink.clear();
        log.read(1, &mut sink);
        assert_eq!(log.inner.lock().unwrap().batches.len(), 0);
        // New appends still delivered after compaction.
        log.append(vec![update(0, 99, 1)]);
        sink.clear();
        log.read(0, &mut sink);
        assert_eq!(sink.len(), 1);
        assert_eq!(sink[0][0], update(0, 99, 1));
    }

    #[test]
    fn append_and_read_sees_own_batch() {
        let log = ProgressLog::<u64>::new(1);
        let mut sink = Vec::new();
        log.append_and_read(0, vec![update(0, 5, 1)], &mut sink);
        assert_eq!(sink.len(), 1);
    }

    #[test]
    fn empty_batches_ignored() {
        let log = ProgressLog::<u64>::new(1);
        log.append(vec![]);
        assert_eq!(log.pending(0), 0);
    }

    #[test]
    fn concurrent_appends_totally_ordered() {
        let log = ProgressLog::<u64>::new(3);
        let threads: Vec<_> = (0..3)
            .map(|w| {
                let log = log.clone();
                std::thread::spawn(move || {
                    for i in 0..100u64 {
                        log.append(vec![update(w, i, 1)]);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // Every worker reads the same sequence.
        let mut seqs = Vec::new();
        for w in 0..3 {
            let mut sink = Vec::new();
            log.read(w, &mut sink);
            let flat: Vec<_> = sink.iter().flat_map(|b| b.iter().cloned()).collect();
            assert_eq!(flat.len(), 300);
            seqs.push(flat);
        }
        assert_eq!(seqs[0], seqs[1]);
        assert_eq!(seqs[1], seqs[2]);
    }
}
