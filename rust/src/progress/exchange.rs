//! The decentralized progress fabric: how workers share pointstamp updates.
//!
//! Following the paper's §4 protocol ("these collected changes are broadcast
//! among unsynchronized workers. Any subset of atomic updates forms a
//! conservative view of the coordination state"), each worker owns a
//! [`Progcaster`] that coalesces its atomic batches of
//! `((Location, T), i64)` updates in a [`ChangeBatch`] and broadcasts them
//! over per-peer SPSC FIFO mailboxes allocated through the worker fabric
//! ([`crate::worker::allocator::Fabric`]). There is **no global sequencer**:
//! workers apply each other's streams in whatever interleaving delivery
//! produces.
//!
//! # Why prefix safety survives without a total order
//!
//! The conservatism invariant — no frontier ever advances past an
//! outstanding pointstamp — needs only two ordering guarantees, both local:
//!
//! 1. **Per-sender FIFO.** A worker pushes the *same* batch sequence into
//!    every peer mailbox, and mailboxes preserve order, so every observer
//!    sees a prefix of each sender's atomic-action history. Batches are
//!    drained from the shared bookkeeping after each operator action, so a
//!    sender's stream reflects its real action order: the `+1` produce
//!    count for a message appears at or before any later drop/downgrade of
//!    the token that authorized producing it.
//! 2. **Produce-before-data-release.** A worker flushes its progress batch
//!    into the peer mailboxes *before* releasing staged data messages to
//!    the data fabric (`worker::Worker` flush path). A consumer can
//!    therefore only record `-1` for a message whose `+1` already sits in
//!    every observer's mailbox.
//!
//! Together these cover every partial view. If an observer has applied the
//! producer's `+1`, the in-flight message is counted directly. If it has
//! not, then — by per-sender FIFO — it also has not applied any later
//! retirement of the authorizing token, so an earlier-or-equal pointstamp
//! from the same sender still holds the frontier. A consumer's `-1`
//! arriving "early" on another mailbox merely drives that location's count
//! transiently negative ([`MutableAntichain`](super::antichain) retains
//! negative entries without letting them shape the frontier). Any subset of
//! delivered batches is therefore a conservative view, exactly as the paper
//! states — the global total order the previous implementation imposed was
//! sufficient but never necessary, and it serialized every worker through
//! one mutex.
//!
//! # The same argument at the per-process fan-out point
//!
//! Across process boundaries the broadcast is *deduplicated*: a flush
//! ships ONE frame per remote process (not one per remote worker),
//! carrying the destination-worker set, and the receiving fabric decodes
//! it once and clones the batch `Arc` into each destination mailbox
//! (`net::fabric::NetFabric::register_broadcast`). Both orderings above
//! survive this unchanged, for the same reasons stated per mechanism:
//!
//! 1. **Per-sender FIFO through the fan-out.** A sender's broadcast
//!    frames ride its process pair's single ordered stream, are decoded by
//!    that link's one recv thread in arrival order, and are appended to
//!    *every* destination inbox before the next frame is touched; the
//!    destination set always names every worker of the process, so no
//!    mailbox is skipped. Each destination therefore still applies a
//!    prefix of the sender's batch sequence — which is all clause (1)
//!    ever required. (Frames that arrive before the channel's decoder is
//!    registered are parked and replayed in arrival order under the same
//!    lock the recv thread must take before its first fan-out, so late
//!    graph construction cannot reorder a stream either.)
//! 2. **Produce-before-data-release across the dedup path.** The
//!    broadcast frame is enqueued toward a remote process before any data
//!    frame it covers (same outbound queue, same stream), and a rejected
//!    broadcast spills into a per-*process* FIFO ([`Progcaster`]'s
//!    `net_spill`) that gates data release exactly like the per-peer ring
//!    spill: while any spill is non-empty, staged data stays put. The
//!    fan-out point only moves the *local* delivery of an already-arrived
//!    frame, and every destination inbox is filled before the recv thread
//!    reads the stream again — so a data frame (which arrives strictly
//!    later on the same stream) can never be consumed before its covering
//!    `+1` sits in every local mailbox.
//!
//! The centralized, totally ordered [`ProgressLog`] is retained below as
//! the measured baseline for `benches/micro_progress.rs` (centralized vs
//! decentralized per-step latency); the runtime itself no longer uses it.

use super::change_batch::ChangeBatch;
use super::location::Location;
use super::timestamp::Timestamp;
use crate::buffer::SharedPool;
use crate::net::fabric::NetBroadcastSender;
use crate::worker::allocator::{Fabric, FabricReceiver, WorkerStats};
use crate::worker::ring::{RingSendError, RingSender};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// One atomic batch of pointstamp updates from one worker.
pub type ProgressBatch<T> = Vec<((Location, T), i64)>;

/// The reserved fabric channel id of the progress plane. Data channels are
/// allocated from 0 upward, so the top id can never collide.
pub const PROGRESS_CHANNEL: usize = usize::MAX;

/// In-flight progress batches tracked for reclamation (ROADMAP
/// "progress-batch pooling"): once every peer has applied and dropped a
/// batch, the [`SharedPool`] hands the same `Vec` + `Arc` back to the next
/// flush, so the steady-state flush path performs no allocation.
const BATCH_POOL_WINDOW: usize = 16;

/// One worker's endpoint of the decentralized progress plane.
///
/// Accumulates the worker's pointstamp updates in a [`ChangeBatch`] (so
/// produce/consume churn cancels locally before ever crossing a thread
/// boundary) and, on [`Progcaster::send`], broadcasts the coalesced batch —
/// one shared `Arc`, no per-peer copy — into every same-process peer's
/// FIFO ring mailbox, plus ONE serialized frame per remote process (the
/// broadcast-dedup path: the frame names the destination-worker set and
/// the receiving fabric fans the decoded batch out locally). The `Vec`
/// *and* the `Arc` of each batch are recycled through a [`SharedPool`]
/// once every peer has dropped its clone, making the steady-state flush
/// allocation-free. The worker's own batch loops back through an internal
/// queue so the owning tracker applies exactly the same stream as every
/// peer.
///
/// Mailbox rings are bounded; a full ring never blocks and never reorders:
/// the batch goes to a per-peer FIFO spill queue and is re-offered before
/// any later batch ([`Progcaster::flush_spill`]). Because a spilled batch
/// has *not* yet reached the peer's mailbox, the worker must not release
/// staged data messages while any spill is pending — see
/// [`Progcaster::has_spill`] and the worker flush path — preserving
/// produce-before-data-release exactly.
pub struct Progcaster<T: Timestamp> {
    index: usize,
    peers: usize,
    /// Coalesces this worker's updates between flushes.
    pending: ChangeBatch<(Location, T)>,
    /// Same-process mailbox send halves, indexed by peer (`None` at
    /// `index` and at every remote worker — those are covered by the
    /// per-process broadcast frames below).
    senders: Vec<Option<RingSender<Arc<ProgressBatch<T>>>>>,
    /// One per-process broadcast sender per REMOTE process, indexed by
    /// process (broadcast dedup: a flush ships ONE frame per remote
    /// process, carrying the destination-worker set; the destination
    /// fabric fans the decoded batch out to its local mailboxes).
    net_senders: Vec<Option<NetBroadcastSender<T>>>,
    /// Per-peer mailbox receive halves (`None` at `index`): rings from
    /// same-process senders, fan-out-fed net endpoints from remote ones.
    receivers: Vec<Option<FabricReceiver<Arc<ProgressBatch<T>>>>>,
    /// Loopback of this worker's own batches, in send order.
    own: VecDeque<Arc<ProgressBatch<T>>>,
    /// Per-peer FIFO of batches rejected by a full ring, re-offered in
    /// order before anything newer.
    spill: Vec<VecDeque<Arc<ProgressBatch<T>>>>,
    /// Per-process FIFO of batches rejected by a full outbound net queue
    /// — the same spill discipline, at per-process granularity.
    net_spill: Vec<VecDeque<Arc<ProgressBatch<T>>>>,
    /// Recycler for batch buffers + `Arc`s (progress-batch pooling).
    pool: SharedPool<ProgressBatch<T>>,
    /// This worker's fabric counters (ring-full stalls).
    stats: Arc<WorkerStats>,
    /// Event tracer: [`Progcaster::send`] emits a `ProgressFlush` span per
    /// broadcast. `None` (the default) costs one branch per send.
    tracer: Option<std::rc::Rc<crate::observe::WorkerTracer>>,
}

impl<T: Timestamp> Progcaster<T> {
    /// Claims worker `index`'s progress mailboxes from `fabric`.
    ///
    /// Every worker sharing the fabric must construct its `Progcaster`
    /// exactly once; the SPSC pairs match up by `(PROGRESS_CHANNEL, from,
    /// to)` key, in any claim order.
    pub fn new(index: usize, peers: usize, fabric: &Fabric) -> Self {
        assert!(index < peers, "worker index {index} out of range for {peers} peers");
        let processes = fabric.processes();
        Progcaster {
            index,
            peers,
            pending: ChangeBatch::new(),
            senders: fabric.local_broadcast_senders(PROGRESS_CHANNEL, index),
            net_senders: fabric.progress_net_senders(PROGRESS_CHANNEL, index),
            receivers: fabric.progress_receivers(PROGRESS_CHANNEL, index),
            own: VecDeque::new(),
            spill: (0..peers).map(|_| VecDeque::new()).collect(),
            net_spill: (0..processes).map(|_| VecDeque::new()).collect(),
            pool: SharedPool::new(BATCH_POOL_WINDOW),
            stats: fabric.stats(index),
            tracer: None,
        }
    }

    /// Installs an event tracer (see [`crate::observe`]): every broadcast
    /// is timed as a `ProgressFlush` span carrying the coalesced update
    /// count.
    pub fn set_tracer(&mut self, tracer: std::rc::Rc<crate::observe::WorkerTracer>) {
        self.tracer = Some(tracer);
    }

    /// The owning worker's index.
    #[inline]
    pub fn index(&self) -> usize {
        self.index
    }

    /// Total number of workers on this progress plane.
    #[inline]
    pub fn peers(&self) -> usize {
        self.peers
    }

    /// Records one pointstamp update into the pending batch.
    #[inline]
    pub fn update(&mut self, location: Location, time: T, diff: i64) {
        self.pending.update((location, time), diff);
    }

    /// Records many pointstamp updates into the pending batch.
    pub fn extend<I: IntoIterator<Item = ((Location, T), i64)>>(&mut self, updates: I) {
        self.pending.extend(updates);
    }

    /// Cheap hint: true iff updates are buffered (they may still net to
    /// zero at [`Progcaster::send`]; `false` means definitely nothing).
    #[inline]
    pub fn has_updates(&self) -> bool {
        self.pending.raw_len() > 0
    }

    /// Upper bound on the pending updates (flush-policy "big batch" check).
    #[inline]
    pub fn pending_len(&self) -> usize {
        self.pending.raw_len()
    }

    /// Coalesces and broadcasts the pending batch to every peer mailbox
    /// (and the loopback queue), returning the batch that went out — or
    /// `None` if the updates netted to nothing.
    ///
    /// The batch buffer and its `Arc` come from the progcaster's recycling
    /// pool: in the steady state (peers keeping up, batches dropped after
    /// application) this path performs no heap allocation.
    ///
    /// The caller (the worker flush path) must invoke this *before*
    /// releasing any staged data messages covered by the batch's produce
    /// counts — and must check [`Progcaster::has_spill`] before releasing:
    /// a spilled batch has not reached its peer's mailbox yet, and data it
    /// covers must wait with it.
    pub fn send(&mut self) -> Option<Arc<ProgressBatch<T>>> {
        if self.pending.is_empty() {
            return None;
        }
        let flush_t0 = self.tracer.as_ref().map(|t| t.now_ns());
        let mut batch = self.pool.checkout();
        Arc::get_mut(&mut batch)
            .expect("checked-out batch is unique")
            .extend(self.pending.drain());
        self.pool.track(&batch);
        // Re-offer older spilled batches first so per-peer FIFO holds.
        self.flush_spill();
        for peer in 0..self.peers {
            let Some(sender) = self.senders[peer].as_mut() else { continue };
            if !self.spill[peer].is_empty() {
                // FIFO: never overtake a spilled predecessor.
                self.spill[peer].push_back(batch.clone());
                continue;
            }
            match sender.send(batch.clone()) {
                Ok(()) => {}
                Err(RingSendError::Full(rejected)) => {
                    self.spill[peer].push_back(rejected);
                    self.stats.note_ring_full();
                }
                // A disconnected peer has shut down; it no longer needs
                // progress (its tracker is gone), so dropping is benign.
                Err(RingSendError::Disconnected(_)) => {}
            }
        }
        // Remote processes: ONE frame each, whatever their worker count
        // (broadcast dedup). Same FIFO spill discipline, per process; the
        // net endpoint counts its own send-queue stalls.
        for process in 0..self.net_senders.len() {
            let Some(sender) = self.net_senders[process].as_mut() else { continue };
            if !self.net_spill[process].is_empty() {
                self.net_spill[process].push_back(batch.clone());
                continue;
            }
            match sender.send(batch.clone()) {
                Ok(()) => {}
                Err(RingSendError::Full(rejected)) => {
                    self.net_spill[process].push_back(rejected);
                }
                Err(RingSendError::Disconnected(_)) => {}
            }
        }
        self.own.push_back(batch.clone());
        if let (Some(tracer), Some(t0)) = (&self.tracer, flush_t0) {
            let dur = tracer.now_ns().saturating_sub(t0);
            tracer.emit(
                crate::observe::EventKind::ProgressFlush,
                t0,
                dur,
                batch.len() as u64,
                self.has_spill() as u64,
            );
        }
        Some(batch)
    }

    /// Re-offers spilled batches to their rings (and per-process frame
    /// queues), oldest first. Returns true iff any batch moved.
    pub fn flush_spill(&mut self) -> bool {
        let mut moved = false;
        for peer in 0..self.peers {
            let Some(sender) = self.senders[peer].as_mut() else { continue };
            while let Some(batch) = self.spill[peer].pop_front() {
                match sender.send(batch) {
                    Ok(()) => moved = true,
                    Err(RingSendError::Full(batch)) => {
                        self.spill[peer].push_front(batch);
                        break;
                    }
                    Err(RingSendError::Disconnected(_)) => {
                        self.spill[peer].clear();
                        break;
                    }
                }
            }
        }
        for process in 0..self.net_senders.len() {
            let Some(sender) = self.net_senders[process].as_mut() else { continue };
            while let Some(batch) = self.net_spill[process].pop_front() {
                match sender.send(batch) {
                    Ok(()) => moved = true,
                    Err(RingSendError::Full(batch)) => {
                        self.net_spill[process].push_front(batch);
                        break;
                    }
                    Err(RingSendError::Disconnected(_)) => {
                        self.net_spill[process].clear();
                        break;
                    }
                }
            }
        }
        moved
    }

    /// True iff some batch is still waiting behind a full peer ring or a
    /// full per-process frame queue. While this holds, the worker must not
    /// release staged data messages — the spilled batch's produce counts
    /// are not yet in every mailbox.
    pub fn has_spill(&self) -> bool {
        self.spill.iter().any(|q| !q.is_empty())
            || self.net_spill.iter().any(|q| !q.is_empty())
    }

    /// Pops the next undelivered batch from one sender's stream (`from ==
    /// index` pops the loopback queue). Exposes per-sender delivery at the
    /// finest grain — the seeded-interleaving tests use this to exercise
    /// adversarial delivery schedules.
    pub fn recv_one(&mut self, from: usize) -> Option<Arc<ProgressBatch<T>>> {
        if from == self.index {
            return self.own.pop_front();
        }
        self.receivers[from].as_mut().and_then(|rx| rx.try_recv().ok())
    }

    /// Drains every undelivered batch (loopback first, then each peer
    /// stream in index order, each in FIFO order) into `into`. Returns
    /// true iff anything arrived.
    pub fn recv_into(&mut self, into: &mut Vec<Arc<ProgressBatch<T>>>) -> bool {
        let start = into.len();
        while let Some(batch) = self.own.pop_front() {
            into.push(batch);
        }
        for receiver in self.receivers.iter_mut().flatten() {
            while let Ok(batch) = receiver.try_recv() {
                into.push(batch);
            }
        }
        into.len() > start
    }

    /// Reuse/allocation counters of the progress-batch pool (telemetry).
    pub fn pool_stats(&self) -> crate::buffer::PoolStats {
        self.pool.stats()
    }
}

// ---------------------------------------------------------------------------
// The centralized baseline (bench-only).
// ---------------------------------------------------------------------------

struct LogInner<T> {
    /// Batches not yet read by every worker; `base` is the global sequence
    /// number of `batches[0]`.
    batches: VecDeque<Arc<ProgressBatch<T>>>,
    base: usize,
    /// Per-worker read cursors (global sequence numbers).
    cursors: Vec<usize>,
}

/// A shared, totally ordered log of atomic progress batches.
///
/// This was the engine's progress plane before the decentralized
/// [`Progcaster`] replaced it: every worker's batches funneled through one
/// `Mutex` to obtain a global sequence — a serialization point the
/// protocol never required. It is kept as the measured baseline for the
/// `micro_progress` benchmark's centralized-vs-decentralized comparison.
pub struct ProgressLog<T> {
    inner: Mutex<LogInner<T>>,
    /// Total batches ever appended — lets readers skip the lock entirely
    /// when they are already caught up.
    tail: AtomicUsize,
}

impl<T: Timestamp> ProgressLog<T> {
    /// Creates a log shared by `peers` workers.
    pub fn new(peers: usize) -> Arc<Self> {
        Arc::new(ProgressLog {
            inner: Mutex::new(LogInner {
                batches: VecDeque::new(),
                base: 0,
                cursors: vec![0; peers],
            }),
            tail: AtomicUsize::new(0),
        })
    }

    /// Appends an atomic batch (no-op if empty).
    pub fn append(&self, batch: ProgressBatch<T>) {
        if batch.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.batches.push_back(Arc::new(batch));
        self.tail.store(inner.base + inner.batches.len(), Ordering::Release);
    }

    /// The global sequence number of the next batch to be appended.
    #[inline]
    pub fn tail(&self) -> usize {
        self.tail.load(Ordering::Acquire)
    }

    /// Appends a batch and reads everything new for `worker` in one
    /// critical section. Returns the worker's new cursor.
    pub fn append_and_read(
        &self,
        worker: usize,
        batch: ProgressBatch<T>,
        read_into: &mut Vec<Arc<ProgressBatch<T>>>,
    ) -> usize {
        let mut inner = self.inner.lock().unwrap();
        if !batch.is_empty() {
            inner.batches.push_back(Arc::new(batch));
            self.tail.store(inner.base + inner.batches.len(), Ordering::Release);
        }
        let base = inner.base;
        let cursor = inner.cursors[worker];
        let start = cursor.saturating_sub(base);
        for i in start..inner.batches.len() {
            read_into.push(inner.batches[i].clone());
        }
        let new_cursor = base + inner.batches.len();
        inner.cursors[worker] = new_cursor;
        // Compact: drop batches read by all workers.
        let min_cursor = *inner.cursors.iter().min().unwrap();
        while inner.base < min_cursor {
            inner.batches.pop_front();
            inner.base += 1;
        }
        new_cursor
    }

    /// Reads all batches `worker` has not yet seen.
    pub fn read(&self, worker: usize, read_into: &mut Vec<Arc<ProgressBatch<T>>>) {
        self.append_and_read(worker, Vec::new(), read_into);
    }

    /// Number of unread batches pending for `worker` (for idle detection).
    pub fn pending(&self, worker: usize) -> usize {
        let inner = self.inner.lock().unwrap();
        (inner.base + inner.batches.len()).saturating_sub(inner.cursors[worker])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn update(n: usize, t: u64, d: i64) -> ((Location, u64), i64) {
        ((Location::source(n, 0), t), d)
    }

    // -- Progcaster (the live path) --

    #[test]
    fn all_peers_receive_identical_batch_sequences() {
        let fabric = Fabric::new(3);
        let mut casters: Vec<Progcaster<u64>> =
            (0..3).map(|w| Progcaster::new(w, 3, &fabric)).collect();

        casters[0].update(Location::source(0, 0), 1, 1);
        casters[0].send().unwrap();
        casters[0].update(Location::source(0, 0), 2, 1);
        casters[0].update(Location::source(0, 0), 1, -1);
        casters[0].send().unwrap();

        // Workers 1 and 2 (and 0's loopback) see the same two batches, in
        // the same order.
        let mut views = Vec::new();
        for caster in casters.iter_mut() {
            let mut got = Vec::new();
            caster.recv_into(&mut got);
            assert_eq!(got.len(), 2);
            views.push(got.iter().map(|b| (**b).clone()).collect::<Vec<_>>());
        }
        assert_eq!(views[0], views[1]);
        assert_eq!(views[1], views[2]);
        assert_eq!(views[0][0], vec![update(0, 1, 1)]);
    }

    #[test]
    fn coalescing_cancels_churn_before_broadcast() {
        let fabric = Fabric::new(2);
        let mut a = Progcaster::<u64>::new(0, 2, &fabric);
        let mut b = Progcaster::<u64>::new(1, 2, &fabric);
        // A retain immediately followed by a drop nets to zero: nothing
        // must cross the thread boundary.
        a.update(Location::source(3, 0), 7, 1);
        a.update(Location::source(3, 0), 7, -1);
        assert!(a.has_updates(), "raw hint is conservative");
        assert!(a.send().is_none(), "net-zero batch must not be sent");
        let mut got = Vec::new();
        assert!(!b.recv_into(&mut got));
        assert!(!a.recv_into(&mut got), "no loopback for net-zero batches");
    }

    #[test]
    fn per_sender_fifo_with_partial_draining() {
        let fabric = Fabric::new(2);
        let mut a = Progcaster::<u64>::new(0, 2, &fabric);
        let mut b = Progcaster::<u64>::new(1, 2, &fabric);
        for t in 0..5u64 {
            a.update(Location::source(0, 0), t, 1);
            a.send().unwrap();
        }
        // Partial draining via recv_one preserves FIFO order.
        for t in 0..5u64 {
            let batch = b.recv_one(0).expect("batch pending");
            assert_eq!(*batch, vec![update(0, t, 1)]);
        }
        assert!(b.recv_one(0).is_none());
        assert!(b.recv_one(1).is_none(), "own loopback empty");
    }

    #[test]
    fn own_batches_loop_back_exactly_once() {
        let fabric = Fabric::new(1);
        let mut solo = Progcaster::<u64>::new(0, 1, &fabric);
        solo.update(Location::source(0, 0), 5, 1);
        solo.send().unwrap();
        let mut got = Vec::new();
        assert!(solo.recv_into(&mut got));
        assert_eq!(got.len(), 1);
        got.clear();
        assert!(!solo.recv_into(&mut got));
    }

    #[test]
    fn concurrent_broadcast_preserves_per_sender_order() {
        let fabric = Fabric::new(3);
        let mut handles = Vec::new();
        for w in 0..3usize {
            let fabric = fabric.clone();
            handles.push(std::thread::spawn(move || {
                let mut caster = Progcaster::<u64>::new(w, 3, &fabric);
                for t in 0..100u64 {
                    caster.update(Location::source(w, 0), t, 1);
                    caster.send().unwrap();
                }
                // Drain until every peer's 100 batches (plus our own 100)
                // have arrived, checking per-sender monotonicity.
                let mut next = [0u64; 3];
                let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
                let mut buf = Vec::new();
                while next.iter().sum::<u64>() < 300 {
                    assert!(std::time::Instant::now() < deadline, "delivery stalled");
                    buf.clear();
                    caster.recv_into(&mut buf);
                    for batch in &buf {
                        let ((loc, t), diff) = batch[0];
                        assert_eq!(diff, 1);
                        assert_eq!(t, next[loc.node], "per-sender FIFO violated");
                        next[loc.node] += 1;
                    }
                    if buf.is_empty() {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        for handle in handles {
            handle.join().unwrap();
        }
    }

    /// Overrunning a peer mailbox must spill — never drop, never reorder:
    /// once the receiver drains, the full per-sender sequence arrives in
    /// FIFO order, and `has_spill` gates exactly the overrun window.
    #[test]
    fn full_mailbox_spills_and_preserves_fifo() {
        let fabric = Fabric::new(2);
        let mut a = Progcaster::<u64>::new(0, 2, &fabric);
        let mut b = Progcaster::<u64>::new(1, 2, &fabric);
        // Push well past the ring capacity without b draining.
        let total = crate::worker::allocator::RING_CAPACITY as u64 + 50;
        for t in 0..total {
            a.update(Location::source(0, 0), t, 1);
            a.send().unwrap();
        }
        assert!(a.has_spill(), "overrun must spill, not drop");
        assert!(fabric.telemetry(0).ring_full_stalls > 0, "stall must be counted");
        // Drain the ring; the spill re-offers in order as space appears.
        let mut next = 0u64;
        while next < total {
            if let Some(batch) = b.recv_one(0) {
                assert_eq!(*batch, vec![update(0, next, 1)], "per-sender FIFO violated");
                next += 1;
            } else {
                assert!(a.has_spill(), "ring empty but stream incomplete: batches lost");
                a.flush_spill();
            }
        }
        assert!(b.recv_one(0).is_none());
        a.flush_spill();
        assert!(!a.has_spill(), "spill must fully drain once the peer catches up");
    }

    // -- ProgressLog (the retained centralized baseline) --

    #[test]
    fn all_workers_see_all_batches_in_order() {
        let log = ProgressLog::<u64>::new(2);
        log.append(vec![update(0, 1, 1)]);
        log.append(vec![update(1, 2, 1)]);

        let mut got0 = Vec::new();
        log.read(0, &mut got0);
        assert_eq!(got0.len(), 2);
        assert_eq!(got0[0][0], update(0, 1, 1));
        assert_eq!(got0[1][0], update(1, 2, 1));

        // Worker 0 re-reading sees nothing new.
        let mut again = Vec::new();
        log.read(0, &mut again);
        assert!(again.is_empty());

        // Worker 1 still sees both.
        let mut got1 = Vec::new();
        log.read(1, &mut got1);
        assert_eq!(got1.len(), 2);
    }

    #[test]
    fn compaction_drops_fully_read_prefix() {
        let log = ProgressLog::<u64>::new(2);
        for i in 0..10 {
            log.append(vec![update(0, i, 1)]);
        }
        let mut sink = Vec::new();
        log.read(0, &mut sink);
        assert_eq!(log.inner.lock().unwrap().batches.len(), 10);
        sink.clear();
        log.read(1, &mut sink);
        assert_eq!(log.inner.lock().unwrap().batches.len(), 0);
        // New appends still delivered after compaction.
        log.append(vec![update(0, 99, 1)]);
        sink.clear();
        log.read(0, &mut sink);
        assert_eq!(sink.len(), 1);
        assert_eq!(sink[0][0], update(0, 99, 1));
    }

    #[test]
    fn append_and_read_sees_own_batch() {
        let log = ProgressLog::<u64>::new(1);
        let mut sink = Vec::new();
        log.append_and_read(0, vec![update(0, 5, 1)], &mut sink);
        assert_eq!(sink.len(), 1);
    }

    #[test]
    fn empty_batches_ignored() {
        let log = ProgressLog::<u64>::new(1);
        log.append(vec![]);
        assert_eq!(log.pending(0), 0);
    }

    #[test]
    fn concurrent_appends_totally_ordered() {
        let log = ProgressLog::<u64>::new(3);
        let threads: Vec<_> = (0..3)
            .map(|w| {
                let log = log.clone();
                std::thread::spawn(move || {
                    for i in 0..100u64 {
                        log.append(vec![update(w, i, 1)]);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // Every worker reads the same sequence.
        let mut seqs = Vec::new();
        for w in 0..3 {
            let mut sink = Vec::new();
            log.read(w, &mut sink);
            let flat: Vec<_> = sink.iter().flat_map(|b| b.iter().cloned()).collect();
            assert_eq!(flat.len(), 300);
            seqs.push(flat);
        }
        assert_eq!(seqs[0], seqs[1]);
        assert_eq!(seqs[1], seqs[2]);
    }
}
