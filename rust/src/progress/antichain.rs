//! Antichains: sets of mutually incomparable timestamps, used to represent
//! frontiers ("lower bounds on the timestamps that operators may yet observe
//! in their inputs", §3).

use super::change_batch::ChangeBatch;
use super::timestamp::PartialOrder;
use std::fmt::Debug;

/// A set of mutually incomparable elements, representing a lower bound.
///
/// A frontier `F` *permits* a timestamp `t` iff some `f ∈ F` has
/// `f.less_equal(t)`. The empty antichain permits nothing — it is the
/// frontier of a complete (closed) input.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Antichain<T> {
    elements: Vec<T>,
}

impl<T: PartialOrder + Clone> Antichain<T> {
    /// An empty antichain (the "complete" frontier: permits no timestamps).
    pub fn new() -> Self {
        Antichain { elements: Vec::new() }
    }

    /// An antichain containing a single element.
    pub fn from_elem(t: T) -> Self {
        Antichain { elements: vec![t] }
    }

    /// Builds an antichain from arbitrary elements, retaining the minimal ones.
    pub fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Self {
        let mut result = Antichain::new();
        for t in iter {
            result.insert(t);
        }
        result
    }

    /// Inserts `t`, returning true iff it was not already dominated.
    ///
    /// Elements of the antichain dominated by `t` are removed.
    pub fn insert(&mut self, t: T) -> bool {
        if self.elements.iter().any(|e| e.less_equal(&t)) {
            false
        } else {
            self.elements.retain(|e| !t.less_equal(e));
            self.elements.push(t);
            true
        }
    }

    /// True iff some element of the antichain is `≤ t` (the frontier permits `t`).
    #[inline]
    pub fn less_equal(&self, t: &T) -> bool {
        self.elements.iter().any(|e| e.less_equal(t))
    }

    /// True iff some element of the antichain is `< t`.
    #[inline]
    pub fn less_than(&self, t: &T) -> bool {
        self.elements.iter().any(|e| e.less_than(t))
    }

    /// True iff every element of `other` is permitted by `self` — i.e.
    /// `self` is a (weakly) earlier bound than `other`.
    pub fn dominates(&self, other: &Antichain<T>) -> bool {
        other.elements.iter().all(|t| self.less_equal(t))
    }

    /// The elements of the antichain.
    #[inline]
    pub fn elements(&self) -> &[T] {
        &self.elements
    }

    /// True iff the antichain is empty (a closed frontier).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.elements.is_empty()
    }

    /// Number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.elements.len()
    }

    /// Clears the antichain.
    pub fn clear(&mut self) {
        self.elements.clear()
    }

    /// Sorts the elements (by the container order), for canonical comparison.
    pub fn sort(&mut self)
    where
        T: Ord,
    {
        self.elements.sort()
    }

    /// Consumes the antichain, returning its elements.
    pub fn into_vec(self) -> Vec<T> {
        self.elements
    }
}

impl<T: PartialOrder + Clone> Default for Antichain<T> {
    fn default() -> Self {
        Antichain::new()
    }
}

impl<T: Debug> Debug for Antichain<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        f.debug_set().entries(self.elements.iter()).finish()
    }
}

/// An antichain derived from signed counts of elements: the frontier of the
/// multiset of elements with positive accumulated count.
///
/// This is the structure the tracker keeps per pointstamp location and per
/// operator input port. `update_iter` applies a batch of `(T, i64)` changes
/// *atomically* (all counts first, then one frontier recomputation) and
/// reports the resulting frontier changes as `(T, i64)` diffs, which is what
/// lets frontier changes be *projected* through path summaries downstream.
#[derive(Clone)]
pub struct MutableAntichain<T: Ord> {
    /// Accumulated counts per element; zero-count entries are purged.
    counts: std::collections::BTreeMap<T, i64>,
    /// Current frontier: minimal elements among those with positive count.
    frontier: Vec<T>,
    /// Scratch buffer for frontier diffs.
    changes: Vec<(T, i64)>,
    /// Scratch buffer reused across `rebuild` calls (hot path: message
    /// send/consume at distinct timestamps rebuilds constantly).
    scratch: Vec<T>,
}

impl<T: PartialOrder + Ord + Clone + Debug> MutableAntichain<T> {
    /// Creates an empty `MutableAntichain`.
    pub fn new() -> Self {
        MutableAntichain {
            counts: std::collections::BTreeMap::new(),
            frontier: Vec::new(),
            changes: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Creates a `MutableAntichain` seeded with count `updates`.
    pub fn from_updates<I: IntoIterator<Item = (T, i64)>>(updates: I) -> Self {
        let mut result = Self::new();
        result.update_iter(updates);
        result
    }

    /// The current frontier.
    #[inline]
    pub fn frontier(&self) -> &[T] {
        &self.frontier
    }

    /// The current frontier as an [`Antichain`].
    pub fn to_antichain(&self) -> Antichain<T> {
        Antichain { elements: self.frontier.clone() }
    }

    /// True iff the frontier permits `t`.
    #[inline]
    pub fn less_equal(&self, t: &T) -> bool {
        self.frontier.iter().any(|e| e.less_equal(t))
    }

    /// True iff some frontier element is strictly less than `t`.
    #[inline]
    pub fn less_than(&self, t: &T) -> bool {
        self.frontier.iter().any(|e| e.less_than(t))
    }

    /// True iff no element has positive count (closed frontier).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.frontier.is_empty()
    }

    /// Total number of distinct elements tracked.
    #[inline]
    pub fn distinct(&self) -> usize {
        self.counts.len()
    }

    /// Applies a batch of count updates atomically and returns the frontier
    /// changes (`-1` for elements leaving the frontier, `+1` for entering).
    ///
    /// Accumulated counts may be *negative* between batches, not just
    /// within one: under the decentralized progress fabric an observer can
    /// apply a consumer's `-1` (heard on one peer's FIFO mailbox) before
    /// the matching producer's `+1` (still queued on another's). Negative
    /// entries are retained until canceled but never contribute to the
    /// frontier; conservatism is preserved because the producer's
    /// authorizing pointstamp — ordered *before* the produce count in the
    /// producer's own update stream — is still counted here (see
    /// [`super::exchange`]).
    pub fn update_iter<I>(&mut self, updates: I) -> std::vec::Drain<'_, (T, i64)>
    where
        I: IntoIterator<Item = (T, i64)>,
    {
        self.changes.clear();
        // Apply all count changes first; track whether the frontier can have
        // changed to avoid recomputation in the (very common) case where
        // updates only touch dominated or still-positive elements.
        let mut dirty = false;
        for (t, diff) in updates {
            if diff == 0 {
                continue;
            }
            let entry = self.counts.entry(t.clone()).or_insert(0);
            let old = *entry;
            *entry += diff;
            let new = *entry;
            if new == 0 {
                self.counts.remove(&t);
            }
            if old <= 0 && new > 0 {
                // Element appeared: frontier changes unless `t` is strictly
                // dominated by an existing frontier element.
                if !self.frontier.iter().any(|f| f.less_equal(&t) && f != &t) {
                    dirty = true;
                }
            } else if old > 0 && new <= 0 {
                // Element vanished: frontier changes only if it was on it.
                if self.frontier.iter().any(|f| f == &t) {
                    dirty = true;
                }
            }
        }
        if dirty {
            self.rebuild();
        }
        self.changes.drain(..)
    }

    /// Rebuilds the frontier from the counts, appending diffs to `changes`.
    fn rebuild(&mut self) {
        let mut new_frontier = std::mem::take(&mut self.scratch);
        new_frontier.clear();
        for (t, &count) in self.counts.iter() {
            // Negative entries (consume observed before its produce) hold
            // nothing: only positive counts define the frontier.
            if count <= 0 {
                continue;
            }
            if !new_frontier.iter().any(|f: &T| f.less_equal(t)) {
                new_frontier.retain(|f| !t.less_equal(f));
                new_frontier.push(t.clone());
            }
        }
        for old in self.frontier.iter() {
            if !new_frontier.contains(old) {
                self.changes.push((old.clone(), -1));
            }
        }
        for new in new_frontier.iter() {
            if !self.frontier.contains(new) {
                self.changes.push((new.clone(), 1));
            }
        }
        self.scratch = std::mem::replace(&mut self.frontier, new_frontier);
    }

    /// Frontier recomputed naively from counts — used by tests to validate
    /// the incremental maintenance.
    pub fn naive_frontier(&self) -> Antichain<T> {
        Antichain::from_iter(
            self.counts
                .iter()
                .filter(|(_, &c)| c > 0)
                .map(|(t, _)| t.clone()),
        )
    }
}

impl<T: PartialOrder + Ord + Clone + Debug> Default for MutableAntichain<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Ord + Debug> Debug for MutableAntichain<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        f.debug_struct("MutableAntichain")
            .field("frontier", &self.frontier)
            .field("counts", &self.counts)
            .finish()
    }
}

/// Accumulates frontier progress changes for several input ports, retaining
/// only net effects. A convenience used by operators that track multiple
/// inputs.
pub type FrontierChanges<T> = ChangeBatch<T>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::progress::timestamp::Product;

    #[test]
    fn antichain_insert_retains_minimal() {
        let mut a = Antichain::new();
        assert!(a.insert(Product::new(2u64, 1u64)));
        assert!(a.insert(Product::new(1u64, 2u64)));
        assert_eq!(a.len(), 2);
        // Dominated by (1,2).
        assert!(!a.insert(Product::new(3u64, 3u64)));
        assert_eq!(a.len(), 2);
        // Dominates both.
        assert!(a.insert(Product::new(1u64, 1u64)));
        assert_eq!(a.elements(), &[Product::new(1, 1)]);
    }

    #[test]
    fn antichain_less_equal() {
        let a = Antichain::from_iter(vec![Product::new(1u64, 2u64), Product::new(2u64, 1u64)]);
        assert!(a.less_equal(&Product::new(1, 2)));
        assert!(a.less_equal(&Product::new(5, 1)));
        assert!(!a.less_equal(&Product::new(0, 0)));
        assert!(!a.less_than(&Product::new(1, 2)));
        assert!(a.less_than(&Product::new(1, 3)));
    }

    #[test]
    fn antichain_empty_permits_nothing() {
        let a = Antichain::<u64>::new();
        assert!(!a.less_equal(&0));
        assert!(a.is_empty());
    }

    #[test]
    fn mutable_antichain_basic() {
        let mut ma = MutableAntichain::new();
        let changes: Vec<_> = ma.update_iter(vec![(3u64, 1)]).collect();
        assert_eq!(changes, vec![(3, 1)]);
        assert_eq!(ma.frontier(), &[3]);

        // A later element does not move the frontier.
        let changes: Vec<_> = ma.update_iter(vec![(5u64, 1)]).collect();
        assert!(changes.is_empty());

        // An earlier element does.
        let changes: Vec<_> = ma.update_iter(vec![(1u64, 1)]).collect();
        assert_eq!(changes, vec![(3, -1), (1, 1)]);

        // Removing the minimum advances to the next.
        let changes: Vec<_> = ma.update_iter(vec![(1u64, -1)]).collect();
        assert_eq!(changes, vec![(1, -1), (3, 1)]);

        // Draining everything empties the frontier.
        let changes: Vec<_> = ma.update_iter(vec![(3u64, -1), (5, -1)]).collect();
        assert_eq!(changes, vec![(3, -1)]);
        assert!(ma.is_empty());
    }

    #[test]
    fn mutable_antichain_same_element_count_churn() {
        let mut ma = MutableAntichain::new();
        ma.update_iter(vec![(2u64, 1)]);
        // More counts at the frontier element: no frontier change.
        let changes: Vec<_> = ma.update_iter(vec![(2u64, 3)]).collect();
        assert!(changes.is_empty());
        let changes: Vec<_> = ma.update_iter(vec![(2u64, -3)]).collect();
        assert!(changes.is_empty());
        assert_eq!(ma.frontier(), &[2]);
    }

    #[test]
    fn mutable_antichain_atomic_batch() {
        let mut ma = MutableAntichain::new();
        ma.update_iter(vec![(4u64, 1)]);
        // Atomic swap 4 -> 2: single rebuild, net diff reported.
        let changes: Vec<_> = ma.update_iter(vec![(2u64, 1), (4, -1)]).collect();
        assert_eq!(changes, vec![(4, -1), (2, 1)]);
    }

    #[test]
    fn mutable_antichain_transient_negative_within_batch() {
        let mut ma = MutableAntichain::new();
        ma.update_iter(vec![(7u64, 1)]);
        // -1 then +1 for the same element within one batch nets to zero.
        let changes: Vec<_> = ma.update_iter(vec![(7u64, -1), (7, 1)]).collect();
        assert!(changes.is_empty());
        assert_eq!(ma.frontier(), &[7]);
    }

    #[test]
    fn mutable_antichain_negative_across_batches() {
        // Decentralized exchange: a consume can be observed before the
        // matching produce. The negative entry must not affect the
        // frontier, and the late produce must cancel it exactly.
        let mut ma = MutableAntichain::new();
        ma.update_iter(vec![(2u64, 1)]); // the authorizing pointstamp
        let changes: Vec<_> = ma.update_iter(vec![(5u64, -1)]).collect();
        assert!(changes.is_empty(), "negative entry must not move the frontier");
        assert_eq!(ma.frontier(), &[2]);
        // The produce arrives: nets to zero, frontier unchanged.
        let changes: Vec<_> = ma.update_iter(vec![(5u64, 1)]).collect();
        assert!(changes.is_empty());
        assert_eq!(ma.frontier(), &[2]);
        // Dropping the authorizing pointstamp closes the frontier.
        ma.update_iter(vec![(2u64, -1)]);
        assert!(ma.is_empty());
        assert_eq!(ma.distinct(), 0, "canceled entries must not leak");
    }

    #[test]
    fn mutable_antichain_partial_order_multiple_minima() {
        let mut ma = MutableAntichain::new();
        let a = Product::new(1u64, 2u64);
        let b = Product::new(2u64, 1u64);
        ma.update_iter(vec![(a, 1), (b, 1)]);
        assert_eq!(ma.frontier().len(), 2);
        let changes: Vec<_> = ma.update_iter(vec![(a, -1)]).collect();
        assert_eq!(changes, vec![(a, -1)]);
        assert_eq!(ma.frontier(), &[b]);
    }

    #[test]
    fn mutable_antichain_matches_naive() {
        // Randomized check (seeded): incremental frontier == naive frontier.
        let mut state = 0x853c49e6748fea9bu64;
        let mut rng = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as usize
        };
        let mut ma = MutableAntichain::new();
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..2000 {
            if live.is_empty() || rng() % 2 == 0 {
                let t = (rng() % 16) as u64;
                live.push(t);
                ma.update_iter(vec![(t, 1)]);
            } else {
                let idx = rng() % live.len();
                let t = live.swap_remove(idx);
                ma.update_iter(vec![(t, -1)]);
            }
            let naive = ma.naive_frontier();
            let mut got = ma.to_antichain();
            got.sort();
            let mut want = naive;
            want.sort();
            assert_eq!(got, want);
        }
    }
}
