//! Antichains: sets of mutually incomparable timestamps, used to represent
//! frontiers ("lower bounds on the timestamps that operators may yet observe
//! in their inputs", §3).
//!
//! # Representation of [`MutableAntichain`]
//!
//! The count-backed antichain is the progress plane's hottest structure:
//! the tracker keeps one per pointstamp location and one per operator input
//! port, and every inbound progress batch folds into several of them. It
//! used to accumulate counts in a `BTreeMap<T, i64>`, which pays a node
//! allocation for every new timestamp — at fine timestamp quanta (the
//! paper's Figure 6/7 regime) that is an allocation per location per
//! quantum, forever, and it is what kept the steady-state worker step from
//! being allocation-free after the data plane was pooled (PR 2).
//!
//! The counts now live in a **flat sorted run**: an inline small-vec of
//! `(T, i64)` pairs (spilling to a reused heap `Vec` only past
//! [`INLINE_RUN`] entries) whose prefix is kept sorted and coalesced with
//! *deferred compaction*, exactly like [`ChangeBatch`]. Updates append in
//! O(1); when the uncompacted tail outgrows the clean prefix the run is
//! sorted in place (`sort_unstable`: no scratch allocation) and equal keys
//! are summed, dropping zero-count entries. Lookups binary-search the
//! clean prefix and scan the short tail. The result: after a location's
//! run capacity warms up, folding count updates performs **zero heap
//! allocations**, and the entries sit contiguous in cache order instead of
//! behind one pointer per tree node.
//!
//! The documented cross-batch negative-count tolerance is preserved:
//! negative entries (a consume observed before its produce, legitimate
//! under the decentralized exchange — see [`super::exchange`]) are retained
//! in the run until canceled but never contribute to the frontier.

use super::change_batch::ChangeBatch;
use super::timestamp::PartialOrder;
use std::fmt::Debug;
use std::mem::MaybeUninit;

/// A set of mutually incomparable elements, representing a lower bound.
///
/// A frontier `F` *permits* a timestamp `t` iff some `f ∈ F` has
/// `f.less_equal(t)`. The empty antichain permits nothing — it is the
/// frontier of a complete (closed) input.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Antichain<T> {
    elements: Vec<T>,
}

impl<T: PartialOrder + Clone> Antichain<T> {
    /// An empty antichain (the "complete" frontier: permits no timestamps).
    pub fn new() -> Self {
        Antichain { elements: Vec::new() }
    }

    /// An antichain containing a single element.
    pub fn from_elem(t: T) -> Self {
        Antichain { elements: vec![t] }
    }

    /// Builds an antichain from arbitrary elements, retaining the minimal ones.
    pub fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Self {
        let mut result = Antichain::new();
        for t in iter {
            result.insert(t);
        }
        result
    }

    /// Inserts `t`, returning true iff it was not already dominated.
    ///
    /// Elements of the antichain dominated by `t` are removed.
    pub fn insert(&mut self, t: T) -> bool {
        if self.elements.iter().any(|e| e.less_equal(&t)) {
            false
        } else {
            self.elements.retain(|e| !t.less_equal(e));
            self.elements.push(t);
            true
        }
    }

    /// True iff some element of the antichain is `≤ t` (the frontier permits `t`).
    #[inline]
    pub fn less_equal(&self, t: &T) -> bool {
        self.elements.iter().any(|e| e.less_equal(t))
    }

    /// True iff some element of the antichain is `< t`.
    #[inline]
    pub fn less_than(&self, t: &T) -> bool {
        self.elements.iter().any(|e| e.less_than(t))
    }

    /// True iff every element of `other` is permitted by `self` — i.e.
    /// `self` is a (weakly) earlier bound than `other`.
    pub fn dominates(&self, other: &Antichain<T>) -> bool {
        other.elements.iter().all(|t| self.less_equal(t))
    }

    /// The elements of the antichain.
    #[inline]
    pub fn elements(&self) -> &[T] {
        &self.elements
    }

    /// True iff the antichain is empty (a closed frontier).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.elements.is_empty()
    }

    /// Number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.elements.len()
    }

    /// Clears the antichain.
    pub fn clear(&mut self) {
        self.elements.clear()
    }

    /// Sorts the elements (by the container order), for canonical comparison.
    pub fn sort(&mut self)
    where
        T: Ord,
    {
        self.elements.sort()
    }

    /// Consumes the antichain, returning its elements.
    pub fn into_vec(self) -> Vec<T> {
        self.elements
    }
}

impl<T: PartialOrder + Clone> Default for Antichain<T> {
    fn default() -> Self {
        Antichain::new()
    }
}

impl<T: Debug> Debug for Antichain<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        f.debug_set().entries(self.elements.iter()).finish()
    }
}

/// Entries a count run stores inline before spilling to the heap. Most
/// locations track one or two live timestamps (a token plus a downgrade in
/// flight), so four pairs cover the steady state without any heap storage
/// at all.
const INLINE_RUN: usize = 4;

/// Flat storage behind [`MutableAntichain`]: an inline array of `(T, i64)`
/// pairs that spills to a heap `Vec` only once a location tracks more than
/// [`INLINE_RUN`] entries. Once spilled it stays spilled — the retained
/// capacity is what makes later updates allocation-free.
enum SmallRun<T> {
    /// Up to [`INLINE_RUN`] entries stored inline; the `usize` is the live
    /// count (slots `0..len` are initialized).
    Inline(usize, [MaybeUninit<(T, i64)>; INLINE_RUN]),
    /// Spilled storage.
    Heap(Vec<(T, i64)>),
}

impl<T> SmallRun<T> {
    fn new() -> Self {
        // SAFETY: an array of `MaybeUninit` requires no initialization.
        SmallRun::Inline(0, unsafe {
            MaybeUninit::<[MaybeUninit<(T, i64)>; INLINE_RUN]>::uninit().assume_init()
        })
    }

    fn len(&self) -> usize {
        match self {
            SmallRun::Inline(len, _) => *len,
            SmallRun::Heap(v) => v.len(),
        }
    }

    fn as_slice(&self) -> &[(T, i64)] {
        match self {
            // SAFETY: the first `len` slots are initialized, and
            // `MaybeUninit<(T, i64)>` has the layout of `(T, i64)`.
            SmallRun::Inline(len, slots) => unsafe {
                std::slice::from_raw_parts(slots.as_ptr() as *const (T, i64), *len)
            },
            SmallRun::Heap(v) => v.as_slice(),
        }
    }

    fn as_mut_slice(&mut self) -> &mut [(T, i64)] {
        match self {
            // SAFETY: as in `as_slice`; exclusive access through `&mut self`.
            SmallRun::Inline(len, slots) => unsafe {
                std::slice::from_raw_parts_mut(slots.as_mut_ptr() as *mut (T, i64), *len)
            },
            SmallRun::Heap(v) => v.as_mut_slice(),
        }
    }

    fn push(&mut self, entry: (T, i64)) {
        if let SmallRun::Heap(v) = self {
            v.push(entry);
            return;
        }
        let SmallRun::Inline(len, slots) = self else { unreachable!() };
        if *len < INLINE_RUN {
            slots[*len].write(entry);
            *len += 1;
            return;
        }
        // Spill: move the inline entries into a heap `Vec` and stay there.
        let mut heap = Vec::with_capacity(2 * INLINE_RUN);
        for slot in slots.iter().take(*len) {
            // SAFETY: slots `0..len` are initialized and each is read
            // exactly once here; `len` is zeroed below so they are never
            // dropped in place.
            heap.push(unsafe { slot.assume_init_read() });
        }
        *len = 0;
        heap.push(entry);
        *self = SmallRun::Heap(heap);
    }

    fn truncate(&mut self, new_len: usize) {
        match self {
            SmallRun::Inline(len, slots) => {
                if new_len >= *len {
                    return;
                }
                for slot in slots.iter_mut().take(*len).skip(new_len) {
                    // SAFETY: slots `new_len..len` are initialized; each is
                    // dropped exactly once, then forgotten by shrinking
                    // `len` below.
                    unsafe { slot.assume_init_drop() };
                }
                *len = new_len;
            }
            SmallRun::Heap(v) => v.truncate(new_len),
        }
    }
}

impl<T> Drop for SmallRun<T> {
    fn drop(&mut self) {
        self.truncate(0);
    }
}

impl<T: Clone> Clone for SmallRun<T> {
    fn clone(&self) -> Self {
        let mut run = SmallRun::new();
        for entry in self.as_slice() {
            run.push(entry.clone());
        }
        run
    }
}

/// An antichain derived from signed counts of elements: the frontier of the
/// multiset of elements with positive accumulated count.
///
/// This is the structure the tracker keeps per pointstamp location and per
/// operator input port. `update_iter` applies a batch of `(T, i64)` changes
/// *atomically* (all counts first, then one frontier recomputation) and
/// reports the resulting frontier changes as `(T, i64)` diffs, which is what
/// lets frontier changes be *projected* through path summaries downstream.
///
/// Counts are stored in a flat sorted run with deferred compaction (see the
/// module docs): the steady-state fold path allocates nothing once the
/// run's capacity has warmed up.
#[derive(Clone)]
pub struct MutableAntichain<T: Ord> {
    /// Accumulated count entries. The first `clean` entries are sorted by
    /// `T`'s total order, have unique keys, and no zero counts; the tail is
    /// pending appends folded in by `compact`.
    updates: SmallRun<T>,
    /// Length of the compacted prefix of `updates`.
    clean: usize,
    /// Current frontier: minimal elements among those with positive count.
    frontier: Vec<T>,
    /// Scratch buffer for frontier diffs.
    changes: Vec<(T, i64)>,
    /// Scratch buffer reused across `rebuild` calls (hot path: message
    /// send/consume at distinct timestamps rebuilds constantly).
    scratch: Vec<T>,
}

impl<T: PartialOrder + Ord + Clone + Debug> MutableAntichain<T> {
    /// Creates an empty `MutableAntichain`.
    pub fn new() -> Self {
        MutableAntichain {
            updates: SmallRun::new(),
            clean: 0,
            frontier: Vec::new(),
            changes: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Creates a `MutableAntichain` seeded with count `updates`.
    pub fn from_updates<I: IntoIterator<Item = (T, i64)>>(updates: I) -> Self {
        let mut result = Self::new();
        result.update_iter(updates);
        result
    }

    /// The current frontier.
    #[inline]
    pub fn frontier(&self) -> &[T] {
        &self.frontier
    }

    /// The current frontier as an [`Antichain`].
    pub fn to_antichain(&self) -> Antichain<T> {
        Antichain { elements: self.frontier.clone() }
    }

    /// True iff the frontier permits `t`.
    #[inline]
    pub fn less_equal(&self, t: &T) -> bool {
        self.frontier.iter().any(|e| e.less_equal(t))
    }

    /// True iff some frontier element is strictly less than `t`.
    #[inline]
    pub fn less_than(&self, t: &T) -> bool {
        self.frontier.iter().any(|e| e.less_than(t))
    }

    /// True iff no element has positive count (closed frontier).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.frontier.is_empty()
    }

    /// Total number of distinct elements tracked (compacts the run).
    #[inline]
    pub fn distinct(&mut self) -> usize {
        self.compact();
        self.updates.len()
    }

    /// The net accumulated count of `t`: binary search in the compacted
    /// prefix plus a scan of the (short, bounded by the compaction policy)
    /// pending tail.
    fn net_count(&self, t: &T) -> i64 {
        let slice = self.updates.as_slice();
        let (clean, tail) = slice.split_at(self.clean);
        let mut sum = match clean.binary_search_by(|entry| entry.0.cmp(t)) {
            Ok(i) => clean[i].1,
            Err(_) => 0,
        };
        for (u, diff) in tail {
            if u == t {
                sum += diff;
            }
        }
        sum
    }

    /// Applies a batch of count updates atomically and returns the frontier
    /// changes (`-1` for elements leaving the frontier, `+1` for entering).
    ///
    /// Accumulated counts may be *negative* between batches, not just
    /// within one: under the decentralized progress fabric an observer can
    /// apply a consumer's `-1` (heard on one peer's FIFO mailbox) before
    /// the matching producer's `+1` (still queued on another's). Negative
    /// entries are retained until canceled but never contribute to the
    /// frontier; conservatism is preserved because the producer's
    /// authorizing pointstamp — ordered *before* the produce count in the
    /// producer's own update stream — is still counted here (see
    /// [`super::exchange`]).
    pub fn update_iter<I>(&mut self, updates: I) -> std::vec::Drain<'_, (T, i64)>
    where
        I: IntoIterator<Item = (T, i64)>,
    {
        self.changes.clear();
        // Append all count changes; track whether the frontier can have
        // changed so the (very common) batch that only touches dominated
        // or still-positive elements skips the rebuild entirely. Every
        // positive count is permitted by the frontier (the frontier is the
        // set of minimal positive elements), so:
        //
        // * a `+diff` can only matter if the frontier does not already
        //   permit `t` AND the accumulated count actually becomes positive
        //   (it may stay ≤ 0 while canceling an early consume);
        // * a `-diff` can only matter if `t` is ON the frontier and its
        //   accumulated count drops to (or below) zero.
        //
        // Staleness of `frontier` inside the loop is benign: any earlier
        // update in the batch that would have changed the frontier has
        // already latched `dirty`, and `rebuild` recomputes from the full
        // post-batch counts.
        let mut dirty = false;
        for (t, diff) in updates {
            if diff == 0 {
                continue;
            }
            if !dirty {
                dirty = if diff > 0 {
                    !self.frontier.iter().any(|f| f.less_equal(&t))
                        && self.net_count(&t) + diff > 0
                } else {
                    self.frontier.iter().any(|f| f == &t)
                        && self.net_count(&t) + diff <= 0
                };
            }
            self.updates.push((t, diff));
            self.maybe_compact();
        }
        if dirty {
            self.rebuild();
        }
        self.changes.drain(..)
    }

    /// Sorts and coalesces the run in place, dropping zero-count entries
    /// (the deferred-compaction step; no allocation).
    fn compact(&mut self) {
        if self.clean == self.updates.len() {
            return;
        }
        let slice = self.updates.as_mut_slice();
        // Unstable sort: in-place, no scratch allocation (equal keys are
        // summed immediately below, so stability is irrelevant).
        slice.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        let len = slice.len();
        let mut write = 0;
        let mut read = 0;
        while read < len {
            let mut sum = slice[read].1;
            let mut next = read + 1;
            while next < len && slice[next].0 == slice[read].0 {
                sum += slice[next].1;
                next += 1;
            }
            if sum != 0 {
                slice.swap(write, read);
                slice[write].1 = sum;
                write += 1;
            }
            read = next;
        }
        self.updates.truncate(write);
        self.clean = write;
    }

    /// Compacts when the pending tail outgrows the clean prefix (amortized
    /// O(log n) sorts; keeps `net_count`'s tail scan short).
    fn maybe_compact(&mut self) {
        let len = self.updates.len();
        if len > INLINE_RUN && len > 2 * self.clean {
            self.compact();
        }
    }

    /// Rebuilds the frontier from the counts, appending diffs to `changes`.
    fn rebuild(&mut self) {
        self.compact();
        let mut new_frontier = std::mem::take(&mut self.scratch);
        new_frontier.clear();
        for (t, count) in self.updates.as_slice() {
            // Negative entries (consume observed before its produce) hold
            // nothing: only positive counts define the frontier.
            if *count <= 0 {
                continue;
            }
            if !new_frontier.iter().any(|f: &T| f.less_equal(t)) {
                new_frontier.retain(|f| !t.less_equal(f));
                new_frontier.push(t.clone());
            }
        }
        for old in self.frontier.iter() {
            if !new_frontier.contains(old) {
                self.changes.push((old.clone(), -1));
            }
        }
        for new in new_frontier.iter() {
            if !self.frontier.contains(new) {
                self.changes.push((new.clone(), 1));
            }
        }
        self.scratch = std::mem::replace(&mut self.frontier, new_frontier);
    }

    /// Frontier recomputed naively from the raw count entries — used by
    /// tests to validate the incremental maintenance. (Deliberately built
    /// on `BTreeMap`, the representation this structure replaced, so the
    /// oracle shares nothing with the sorted-run code paths.)
    pub fn naive_frontier(&self) -> Antichain<T> {
        let mut counts = std::collections::BTreeMap::new();
        for (t, diff) in self.updates.as_slice() {
            *counts.entry(t.clone()).or_insert(0i64) += *diff;
        }
        Antichain::from_iter(counts.into_iter().filter(|&(_, c)| c > 0).map(|(t, _)| t))
    }
}

impl<T: PartialOrder + Ord + Clone + Debug> Default for MutableAntichain<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Ord + Debug> Debug for MutableAntichain<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        f.debug_struct("MutableAntichain")
            .field("frontier", &self.frontier)
            .field("updates", &self.updates.as_slice())
            .finish()
    }
}

/// Accumulates frontier progress changes for several input ports, retaining
/// only net effects. A convenience used by operators that track multiple
/// inputs.
pub type FrontierChanges<T> = ChangeBatch<T>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::progress::timestamp::Product;
    use crate::testing::property;

    #[test]
    fn antichain_insert_retains_minimal() {
        let mut a = Antichain::new();
        assert!(a.insert(Product::new(2u64, 1u64)));
        assert!(a.insert(Product::new(1u64, 2u64)));
        assert_eq!(a.len(), 2);
        // Dominated by (1,2).
        assert!(!a.insert(Product::new(3u64, 3u64)));
        assert_eq!(a.len(), 2);
        // Dominates both.
        assert!(a.insert(Product::new(1u64, 1u64)));
        assert_eq!(a.elements(), &[Product::new(1, 1)]);
    }

    #[test]
    fn antichain_less_equal() {
        let a = Antichain::from_iter(vec![Product::new(1u64, 2u64), Product::new(2u64, 1u64)]);
        assert!(a.less_equal(&Product::new(1, 2)));
        assert!(a.less_equal(&Product::new(5, 1)));
        assert!(!a.less_equal(&Product::new(0, 0)));
        assert!(!a.less_than(&Product::new(1, 2)));
        assert!(a.less_than(&Product::new(1, 3)));
    }

    #[test]
    fn antichain_empty_permits_nothing() {
        let a = Antichain::<u64>::new();
        assert!(!a.less_equal(&0));
        assert!(a.is_empty());
    }

    #[test]
    fn mutable_antichain_basic() {
        let mut ma = MutableAntichain::new();
        let changes: Vec<_> = ma.update_iter(vec![(3u64, 1)]).collect();
        assert_eq!(changes, vec![(3, 1)]);
        assert_eq!(ma.frontier(), &[3]);

        // A later element does not move the frontier.
        let changes: Vec<_> = ma.update_iter(vec![(5u64, 1)]).collect();
        assert!(changes.is_empty());

        // An earlier element does.
        let changes: Vec<_> = ma.update_iter(vec![(1u64, 1)]).collect();
        assert_eq!(changes, vec![(3, -1), (1, 1)]);

        // Removing the minimum advances to the next.
        let changes: Vec<_> = ma.update_iter(vec![(1u64, -1)]).collect();
        assert_eq!(changes, vec![(1, -1), (3, 1)]);

        // Draining everything empties the frontier.
        let changes: Vec<_> = ma.update_iter(vec![(3u64, -1), (5, -1)]).collect();
        assert_eq!(changes, vec![(3, -1)]);
        assert!(ma.is_empty());
    }

    #[test]
    fn mutable_antichain_same_element_count_churn() {
        let mut ma = MutableAntichain::new();
        ma.update_iter(vec![(2u64, 1)]);
        // More counts at the frontier element: no frontier change.
        let changes: Vec<_> = ma.update_iter(vec![(2u64, 3)]).collect();
        assert!(changes.is_empty());
        let changes: Vec<_> = ma.update_iter(vec![(2u64, -3)]).collect();
        assert!(changes.is_empty());
        assert_eq!(ma.frontier(), &[2]);
    }

    #[test]
    fn mutable_antichain_atomic_batch() {
        let mut ma = MutableAntichain::new();
        ma.update_iter(vec![(4u64, 1)]);
        // Atomic swap 4 -> 2: single rebuild, net diff reported.
        let changes: Vec<_> = ma.update_iter(vec![(2u64, 1), (4, -1)]).collect();
        assert_eq!(changes, vec![(4, -1), (2, 1)]);
    }

    #[test]
    fn mutable_antichain_transient_negative_within_batch() {
        let mut ma = MutableAntichain::new();
        ma.update_iter(vec![(7u64, 1)]);
        // -1 then +1 for the same element within one batch nets to zero.
        let changes: Vec<_> = ma.update_iter(vec![(7u64, -1), (7, 1)]).collect();
        assert!(changes.is_empty());
        assert_eq!(ma.frontier(), &[7]);
    }

    #[test]
    fn mutable_antichain_negative_across_batches() {
        // Decentralized exchange: a consume can be observed before the
        // matching produce. The negative entry must not affect the
        // frontier, and the late produce must cancel it exactly.
        let mut ma = MutableAntichain::new();
        ma.update_iter(vec![(2u64, 1)]); // the authorizing pointstamp
        let changes: Vec<_> = ma.update_iter(vec![(5u64, -1)]).collect();
        assert!(changes.is_empty(), "negative entry must not move the frontier");
        assert_eq!(ma.frontier(), &[2]);
        // The produce arrives: nets to zero, frontier unchanged.
        let changes: Vec<_> = ma.update_iter(vec![(5u64, 1)]).collect();
        assert!(changes.is_empty());
        assert_eq!(ma.frontier(), &[2]);
        // Dropping the authorizing pointstamp closes the frontier.
        ma.update_iter(vec![(2u64, -1)]);
        assert!(ma.is_empty());
        assert_eq!(ma.distinct(), 0, "canceled entries must not leak");
    }

    #[test]
    fn mutable_antichain_partial_order_multiple_minima() {
        let mut ma = MutableAntichain::new();
        let a = Product::new(1u64, 2u64);
        let b = Product::new(2u64, 1u64);
        ma.update_iter(vec![(a, 1), (b, 1)]);
        assert_eq!(ma.frontier().len(), 2);
        let changes: Vec<_> = ma.update_iter(vec![(a, -1)]).collect();
        assert_eq!(changes, vec![(a, -1)]);
        assert_eq!(ma.frontier(), &[b]);
    }

    #[test]
    fn mutable_antichain_matches_naive() {
        // Randomized check (seeded): incremental frontier == naive frontier.
        let mut state = 0x853c49e6748fea9bu64;
        let mut rng = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as usize
        };
        let mut ma = MutableAntichain::new();
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..2000 {
            if live.is_empty() || rng() % 2 == 0 {
                let t = (rng() % 16) as u64;
                live.push(t);
                ma.update_iter(vec![(t, 1)]);
            } else {
                let idx = rng() % live.len();
                let t = live.swap_remove(idx);
                ma.update_iter(vec![(t, -1)]);
            }
            let naive = ma.naive_frontier();
            let mut got = ma.to_antichain();
            got.sort();
            let mut want = naive;
            want.sort();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn small_run_spills_and_keeps_contents() {
        let mut ma = MutableAntichain::new();
        // Push more distinct live elements than the inline capacity holds:
        // the run must spill to the heap without losing or reordering
        // counts.
        let n = (INLINE_RUN as u64) * 4;
        for t in (0..n).rev() {
            let changes: Vec<_> = ma.update_iter(vec![(t, 1)]).collect();
            // Each insert is a new minimum: frontier moves every time.
            if t == n - 1 {
                assert_eq!(changes, vec![(t, 1)]);
            } else {
                assert_eq!(changes, vec![(t + 1, -1), (t, 1)]);
            }
        }
        assert_eq!(ma.distinct() as u64, n);
        assert_eq!(ma.frontier(), &[0]);
        // Remove from the bottom: the frontier walks back up.
        for t in 0..n - 1 {
            let changes: Vec<_> = ma.update_iter(vec![(t, -1)]).collect();
            assert_eq!(changes, vec![(t, -1), (t + 1, 1)]);
        }
    }

    /// The sorted-run antichain agrees with a `BTreeMap` reference model
    /// under randomized update sequences, including cross-batch negative
    /// counts (consume observed before produce) and interleaved
    /// `frontier()` / `less_equal` probes. The emitted diffs are also
    /// checked: replaying them against a shadow copy of the frontier must
    /// reproduce the reported frontier exactly.
    #[test]
    fn sorted_run_matches_btreemap_model_u64() {
        property("sorted_run_matches_btreemap_model_u64", 25, |_case, rng| {
            let mut ma = MutableAntichain::new();
            let mut model: std::collections::BTreeMap<u64, i64> =
                std::collections::BTreeMap::new();
            // Produces owed to the model: each entry cancels an early
            // consume sent in a previous batch.
            let mut owed: Vec<u64> = Vec::new();
            let mut shadow: Vec<u64> = Vec::new();
            for _step in 0..300 {
                let mut batch: Vec<(u64, i64)> = Vec::new();
                for _ in 0..rng.range(1, 5) {
                    let t = rng.below(12);
                    match rng.below(10) {
                        // Ordinary produce.
                        0..=4 => batch.push((t, 1)),
                        // Ordinary consume (may drive a count negative —
                        // the model tolerates it, the antichain must too).
                        5..=7 => batch.push((t, -1)),
                        // Early consume: the matching produce arrives in
                        // some later batch.
                        8 => {
                            batch.push((t, -1));
                            owed.push(t);
                        }
                        // Settle one owed produce, if any.
                        _ => {
                            if let Some(t) = owed.pop() {
                                batch.push((t, 1));
                            }
                        }
                    }
                }
                for &(t, d) in &batch {
                    *model.entry(t).or_insert(0) += d;
                }
                // Apply the batch and replay the diffs onto the shadow.
                for (t, d) in ma.update_iter(batch) {
                    if d > 0 {
                        shadow.push(t);
                    } else {
                        let pos = shadow
                            .iter()
                            .position(|&s| s == t)
                            .expect("diff removed an element not on the shadow frontier");
                        shadow.swap_remove(pos);
                    }
                }
                // Model frontier: minimal elements with positive count
                // (u64 is totally ordered: the single minimum).
                let want: Vec<u64> = model
                    .iter()
                    .filter(|(_, &c)| c > 0)
                    .map(|(&t, _)| t)
                    .take(1)
                    .collect();
                let mut got = ma.frontier().to_vec();
                got.sort();
                assert_eq!(got, want, "frontier diverged from the BTreeMap model");
                shadow.sort();
                assert_eq!(shadow, want, "emitted diffs diverged from the frontier");
                shadow = got;
                // Interleaved probes.
                for _ in 0..3 {
                    let p = rng.below(14);
                    let want_le = want.iter().any(|&f| f <= p);
                    assert_eq!(ma.less_equal(&p), want_le, "less_equal({p}) diverged");
                }
            }
        });
    }

    /// Same model check for a partially ordered timestamp: frontiers with
    /// multiple minima, domination by incomparable elements.
    #[test]
    fn sorted_run_matches_btreemap_model_product() {
        property("sorted_run_matches_btreemap_model_product", 25, |_case, rng| {
            type P = Product<u64, u64>;
            let mut ma = MutableAntichain::<P>::new();
            let mut model: std::collections::BTreeMap<P, i64> =
                std::collections::BTreeMap::new();
            let mut owed: Vec<P> = Vec::new();
            for _step in 0..200 {
                let mut batch: Vec<(P, i64)> = Vec::new();
                for _ in 0..rng.range(1, 4) {
                    let t = Product::new(rng.below(5), rng.below(5));
                    match rng.below(8) {
                        0..=3 => batch.push((t, 1)),
                        4..=5 => batch.push((t, -1)),
                        6 => {
                            batch.push((t, -1));
                            owed.push(t);
                        }
                        _ => {
                            if let Some(t) = owed.pop() {
                                batch.push((t, 1));
                            }
                        }
                    }
                }
                for &(t, d) in &batch {
                    *model.entry(t).or_insert(0) += d;
                }
                ma.update_iter(batch);
                // Model frontier: minimal positive-count elements.
                let positive: Vec<P> =
                    model.iter().filter(|(_, &c)| c > 0).map(|(&t, _)| t).collect();
                let mut want = Antichain::from_iter(positive.iter().cloned());
                want.sort();
                let mut got = ma.to_antichain();
                got.sort();
                assert_eq!(got, want, "frontier diverged from the BTreeMap model");
                // Interleaved probes.
                for _ in 0..3 {
                    let p = Product::new(rng.below(6), rng.below(6));
                    assert_eq!(
                        ma.less_equal(&p),
                        want.less_equal(&p),
                        "less_equal({p:?}) diverged"
                    );
                }
            }
        });
    }
}
