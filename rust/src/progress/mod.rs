//! Progress tracking: the substrate that turns timestamp-token counts into
//! per-port frontiers.
//!
//! The coordination state of the system is a multiset of *pointstamps*
//! `(Location, T)` (§3.2 of the paper): live timestamp tokens are counted at
//! operator *source* (output) ports, and undelivered message batches are
//! counted at *target* (input) ports. This module provides:
//!
//! * [`timestamp`] — partial orders, the `Timestamp` trait, path summaries;
//! * [`antichain`] — `Antichain` and count-backed `MutableAntichain`;
//! * [`change_batch`] — compacting `(T, i64)` update batches (the "shared
//!   bookkeeping data structure" of §4);
//! * [`location`] — pointstamp locations (node/port/direction);
//! * [`reachability`] — path-summary closure over the dataflow graph;
//! * [`tracker`] — the per-worker tracker that folds pointstamp updates into
//!   per-port frontier antichains by projection through path summaries;
//! * [`exchange`] — the decentralized progress fabric: per-worker
//!   `Progcaster`s broadcast atomic update batches over per-peer FIFO
//!   mailboxes, no global sequencer (§4: any subset of atomic updates is a
//!   conservative view of the coordination state).

pub mod antichain;
pub mod change_batch;
pub mod exchange;
pub mod location;
pub mod reachability;
pub mod timestamp;
pub mod tracker;

pub use antichain::{Antichain, MutableAntichain};
pub use change_batch::ChangeBatch;
pub use location::{Location, Port};
pub use timestamp::{PartialOrder, PathSummary, Timestamp};
