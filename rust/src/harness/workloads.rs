//! The paper's benchmark dataflows, one per coordination mechanism.
//!
//! Each builder returns a mechanism-agnostic `(input, probe)` pair so the
//! open-loop driver ([`super::openloop`]) can run any `(workload,
//! mechanism)` combination. Latency semantics are aligned: a timestamp `t`
//! is *complete* when the sink can prove no more data at `≤ t` will arrive
//! (frontier passed `t` for tokens/notifications, sink watermark `> t` for
//! watermarks).

use crate::coordination::notificator::Notificator;
use crate::coordination::watermark::{
    WatermarkExt, WmInput, WmLogic, WmProbeHandle, WmRecord, WmWiring, WM_CLOSED,
};
use crate::coordination::Mechanism;
use crate::dataflow::channels::Pact;
use crate::dataflow::input::InputSession;
use crate::dataflow::operator::OperatorExt;
use crate::dataflow::probe::{ProbeExt, ProbeHandle};
use crate::operators::noop::NoopExt;
use crate::operators::wordcount::WordCountExt;
use crate::worker::Worker;
use std::collections::HashMap;

/// Mechanism-agnostic input handle for the benchmark workloads, generic
/// over the record type (`u64` words for §7.2/§7.3, `nexmark::Event` for
/// §7.4).
pub enum WorkloadInput<D: crate::dataflow::channels::Data = u64> {
    /// Token/notification workloads feed a plain engine input.
    Engine(InputSession<u64, D>),
    /// Watermark workloads feed data + in-stream marks.
    Wm(WmInput<D>),
}

impl<D: crate::dataflow::channels::Data> WorkloadInput<D> {
    /// Sends one record with event time `te` (the current quantized stamp).
    #[inline]
    pub fn send(&mut self, te: u64, record: D) {
        match self {
            WorkloadInput::Engine(input) => input.send(record),
            WorkloadInput::Wm(input) => input.send(te, record),
        }
    }

    /// Advances the source to quantized time `t` (engine epoch or
    /// watermark).
    pub fn advance(&mut self, t: u64) {
        match self {
            WorkloadInput::Engine(input) => input.advance_to(t),
            WorkloadInput::Wm(input) => input.advance_watermark(t),
        }
    }

    /// The source's current time.
    pub fn time(&self) -> u64 {
        match self {
            WorkloadInput::Engine(input) => *input.time(),
            WorkloadInput::Wm(input) => input.watermark(),
        }
    }

    /// Closes the input.
    pub fn close(&mut self) {
        match self {
            WorkloadInput::Engine(input) => input.close(),
            WorkloadInput::Wm(input) => input.close(),
        }
    }
}

/// Mechanism-agnostic completion probe.
#[derive(Clone)]
pub enum CompletionProbe {
    /// Engine frontier (tokens / notifications).
    Engine(ProbeHandle<u64>),
    /// Sink watermark.
    Wm(WmProbeHandle),
}

impl CompletionProbe {
    /// True iff no more data at timestamps `≤ t` can arrive at the sink.
    #[inline]
    pub fn complete(&self, t: u64) -> bool {
        match self {
            CompletionProbe::Engine(probe) => !probe.less_equal(&t),
            CompletionProbe::Wm(probe) => probe.watermark() > t,
        }
    }

    /// True iff the dataflow has fully drained.
    pub fn done(&self) -> bool {
        match self {
            CompletionProbe::Engine(probe) => probe.done(),
            CompletionProbe::Wm(probe) => probe.done(),
        }
    }
}

/// The Naiad-notification word count: buffers words per timestamp, requests
/// a notification per *distinct* timestamp, and emits each tally only when
/// its notification is delivered — one system interaction per timestamp,
/// which is exactly what collapses for fine-grained quanta (§7.2).
fn word_count_notify(
    stream: &crate::dataflow::stream::Stream<u64, u64>,
) -> crate::dataflow::stream::Stream<u64, (u64, u64)> {
    stream.unary_frontier(
        Pact::exchange(|w: &u64| *w),
        "word_count_notify",
        |tok, info| {
            drop(tok);
            let mut notificator = Notificator::new(info.activator.clone());
            let mut stash: HashMap<u64, Vec<u64>> = HashMap::new();
            let mut counts: HashMap<u64, u64> = HashMap::new();
            let mut frontier_buf: Vec<u64> = Vec::new();
            move |input: &mut _, output: &mut _| {
                while let Some((token, data)) = input.next() {
                    let t = *token.time();
                    stash.entry(t).or_insert_with(|| {
                        notificator.notify_at(token.retain());
                        Vec::new()
                    });
                    stash.get_mut(&t).expect("stashed").extend(data);
                }
                frontier_buf.clear();
                frontier_buf.extend_from_slice(input.frontier().frontier());
                // ONE notification per invocation (Naiad's contract).
                if let Some(token) = notificator.next(&frontier_buf) {
                    if let Some(words) = stash.remove(token.time()) {
                        let mut session = output.session(&token);
                        for word in words {
                            let count = counts.entry(word).or_insert(0);
                            *count += 1;
                            session.give((word, *count));
                        }
                    }
                }
            }
        },
    )
}

/// The Flink-watermark word count logic (counts are emitted immediately;
/// marks drive only completion).
struct WmWordCount {
    counts: HashMap<u64, u64>,
}
impl WmLogic<u64, (u64, u64)> for WmWordCount {
    fn on_data(&mut self, te: u64, word: u64, out: &mut Vec<(u64, (u64, u64))>) {
        let count = self.counts.entry(word).or_insert(0);
        *count += 1;
        out.push((te, (word, *count)));
    }
    fn on_watermark(&mut self, _wm: u64, _out: &mut Vec<(u64, (u64, u64))>) {}
}

/// Builds the §7.2 word-count dataflow under `mechanism`.
pub fn build_word_count(
    worker: &mut Worker<u64>,
    mechanism: Mechanism,
) -> (WorkloadInput, CompletionProbe) {
    match mechanism {
        Mechanism::Tokens => {
            let (input, stream) = worker.new_input::<u64>();
            let probe = stream.word_count().probe();
            (WorkloadInput::Engine(input), CompletionProbe::Engine(probe))
        }
        Mechanism::Notifications => {
            let (input, stream) = worker.new_input::<u64>();
            let probe = word_count_notify(&stream).probe();
            (WorkloadInput::Engine(input), CompletionProbe::Engine(probe))
        }
        Mechanism::WatermarksX | Mechanism::WatermarksP => {
            // The word count must aggregate globally, so data is exchanged
            // in both wirings; -P is only meaningful for pipelines (Fig 8).
            let (input, stream) = WmInput::<u64>::new(worker);
            let counted = stream.wm_unary(
                WmWiring::Exchanged,
                "wm_word_count",
                |w: &u64| *w,
                WmWordCount { counts: HashMap::new() },
            );
            let probe = counted.wm_probe(|_| {});
            (WorkloadInput::Wm(input), CompletionProbe::Wm(probe))
        }
    }
}

/// Builds the §7.3 idle-pipeline dataflow: one exchange off the input, then
/// `chain` no-op operators, under `mechanism`.
///
/// Tokens and notifications share the no-op implementation: a Naiad no-op
/// forwards data on receipt and requests no notifications, so the two
/// mechanisms coincide on idle fragments — as the paper's Figure 8 shows
/// (both flat). Watermarks differ by wiring: `-X` broadcasts marks at every
/// stage, `-P` keeps the chain worker-local.
pub fn build_noop_chain(
    worker: &mut Worker<u64>,
    mechanism: Mechanism,
    chain: usize,
) -> (WorkloadInput, CompletionProbe) {
    match mechanism {
        Mechanism::Tokens | Mechanism::Notifications => {
            let (input, stream) = worker.new_input::<u64>();
            let probe = stream
                .unary(Pact::exchange(|w: &u64| *w), "head_exchange", |tok, _| {
                    drop(tok);
                    move |input: &mut _, output: &mut _| {
                        while let Some((token, data)) = input.next() {
                            output.session(&token).give_batch(data);
                        }
                    }
                })
                .noop_chain(chain)
                .probe();
            (WorkloadInput::Engine(input), CompletionProbe::Engine(probe))
        }
        Mechanism::WatermarksX => {
            let (input, stream) = WmInput::<u64>::new(worker);
            let probe = stream
                .wm_noop_chain(WmWiring::Exchanged, chain)
                .wm_probe(|_| {});
            (WorkloadInput::Wm(input), CompletionProbe::Wm(probe))
        }
        Mechanism::WatermarksP => {
            let (input, stream) = WmInput::<u64>::new(worker);
            let probe = stream
                .wm_noop_chain(WmWiring::Pipelined, chain)
                .wm_probe(|_| {});
            (WorkloadInput::Wm(input), CompletionProbe::Wm(probe))
        }
    }
}

/// Closes a workload and steps the worker until fully drained.
pub fn drain<D: crate::dataflow::channels::Data>(
    worker: &mut Worker<u64>,
    input: &mut WorkloadInput<D>,
    probe: &CompletionProbe,
) {
    input.close();
    worker.step_while(|| !probe.done());
}

/// The closing timestamp used by watermark workloads.
pub const CLOSED: u64 = WM_CLOSED;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::worker::execute::execute;

    /// All mechanisms produce a complete signal for every fed timestamp.
    #[test]
    fn all_mechanisms_complete_word_count() {
        for mechanism in Mechanism::all() {
            let results = execute::<u64, _, _>(
                Config { workers: 2, pin_workers: false, ..Default::default() },
                move |worker| {
                    let (mut input, probe) = build_word_count(worker, mechanism);
                    for step in 1..=5u64 {
                        let t = step * 1000;
                        for w in 0..16u64 {
                            input.send(t, w);
                        }
                        input.advance(t + 1000);
                        let deadline = std::time::Instant::now()
                            + std::time::Duration::from_secs(5);
                        while !probe.complete(t) {
                            worker.step();
                            assert!(
                                std::time::Instant::now() < deadline,
                                "{mechanism:?} stuck at t={t}"
                            );
                        }
                    }
                    drain(worker, &mut input, &probe);
                    true
                },
            );
            assert_eq!(results, vec![true, true], "{mechanism:?}");
        }
    }

    /// All mechanisms drain an idle no-op chain.
    #[test]
    fn all_mechanisms_complete_noop_chain() {
        for mechanism in Mechanism::all() {
            let results = execute::<u64, _, _>(
                Config { workers: 2, pin_workers: false, ..Default::default() },
                move |worker| {
                    let (mut input, probe) = build_noop_chain(worker, mechanism, 16);
                    for step in 1..=5u64 {
                        let t = step * 1000;
                        input.advance(t);
                        let deadline = std::time::Instant::now()
                            + std::time::Duration::from_secs(5);
                        while !probe.complete(t.saturating_sub(1)) {
                            worker.step();
                            assert!(
                                std::time::Instant::now() < deadline,
                                "{mechanism:?} stuck at t={t}"
                            );
                        }
                    }
                    drain(worker, &mut input, &probe);
                    true
                },
            );
            assert_eq!(results, vec![true, true], "{mechanism:?}");
        }
    }
}
