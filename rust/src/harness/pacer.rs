//! Absolute-deadline pacing for open-loop load generation.
//!
//! A [`Pacer`] schedules event `k` at `epoch + k * period` — the deadline
//! grid is fixed at construction and never re-derived from "now", so
//! neither sleep jitter nor a slow consumer shifts later deadlines
//! (sleep-until, not sleep-for). When the caller falls behind, overdue
//! deadlines are handed back immediately, one per call, so the backlog is
//! worked off at full speed and each event still carries the stamp it was
//! *scheduled* for. Measuring latency against those scheduled stamps is
//! what keeps an open-loop harness honest under stall: the delay shows up
//! in the recorded latencies instead of silently stretching the schedule
//! (coordinated omission).

use std::time::{Duration, Instant};

/// Fixed-rate absolute-deadline scheduler.
#[derive(Clone, Debug)]
pub struct Pacer {
    epoch: Instant,
    period: Duration,
    /// Index of the next deadline to hand out.
    next: u64,
}

impl Pacer {
    /// A pacer whose deadline `k` is `epoch + k * period`, starting at
    /// `k = 1` (the epoch itself is the zeroth boundary, not an event).
    pub fn new(epoch: Instant, period: Duration) -> Self {
        assert!(period > Duration::ZERO, "pacer period must be positive");
        Pacer { epoch, period, next: 1 }
    }

    /// A pacer for `rate` events per second starting now.
    pub fn per_second(rate: u64) -> Self {
        assert!(rate > 0, "pacer rate must be positive");
        Pacer::new(Instant::now(), Duration::from_nanos(1_000_000_000 / rate.max(1)))
    }

    /// The experiment epoch (deadline zero).
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// The next deadline to be handed out.
    pub fn next_deadline(&self) -> Instant {
        self.epoch + Self::offset_of(self.period, self.next)
    }

    /// Scheduled offset of deadline `k` from the epoch (`k * period`,
    /// saturating far beyond any experiment horizon).
    fn offset_of(period: Duration, k: u64) -> Duration {
        Duration::from_nanos((period.as_nanos() as u64).saturating_mul(k))
    }

    /// Blocks until the next deadline and returns its scheduled offset
    /// from the epoch. Returns immediately when the deadline is already
    /// past — the caller drains the backlog at full speed, and the
    /// returned offset is still the *scheduled* time, never "now".
    pub fn wait_next(&mut self) -> Duration {
        let deadline = self.next_deadline();
        let scheduled = Self::offset_of(self.period, self.next);
        self.next += 1;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return scheduled;
            }
            // Sleep UNTIL the absolute deadline; a short final spin would
            // buy precision at the cost of a busy core, which the harness
            // deliberately avoids — quantization absorbs sub-quantum
            // jitter.
            std::thread::sleep(deadline - now);
        }
    }

    /// How many deadlines are currently overdue (0 when on schedule).
    pub fn backlog(&self) -> u64 {
        let elapsed = self.epoch.elapsed();
        let due = (elapsed.as_nanos() / self.period.as_nanos().max(1)) as u64;
        due.saturating_sub(self.next.saturating_sub(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadlines_are_absolute_not_relative() {
        // Miss several deadlines, then catch up: the pacer must hand back
        // every overdue deadline immediately with its original scheduled
        // offset — no re-anchoring to "now".
        let period = Duration::from_millis(5);
        let mut pacer = Pacer::new(Instant::now(), period);
        std::thread::sleep(period * 4);
        let mut offsets = Vec::new();
        let t0 = Instant::now();
        for _ in 0..3 {
            offsets.push(pacer.wait_next());
        }
        // All three were overdue: handed back without sleeping.
        assert!(t0.elapsed() < period * 2, "overdue deadlines must not sleep");
        assert_eq!(offsets, vec![period, period * 2, period * 3]);
    }

    #[test]
    fn on_schedule_waits_for_the_grid() {
        let period = Duration::from_millis(10);
        let epoch = Instant::now();
        let mut pacer = Pacer::new(epoch, period);
        let first = pacer.wait_next();
        assert_eq!(first, period);
        // The wait ended at (or after) the absolute deadline.
        assert!(epoch.elapsed() >= period);
    }

    #[test]
    fn backlog_counts_overdue_deadlines() {
        let period = Duration::from_millis(5);
        let mut pacer = Pacer::new(Instant::now(), period);
        std::thread::sleep(period * 3);
        assert!(pacer.backlog() >= 2, "backlog {}", pacer.backlog());
        while pacer.backlog() > 0 {
            pacer.wait_next();
        }
        assert_eq!(pacer.backlog(), 0);
    }
}
