//! A deterministic checkpoint/recovery workload: the crash-recovery
//! equivalent of the open-loop harness, built for *exact* output checks
//! rather than latency measurement.
//!
//! Every run feeds the same words: epoch `e` carries `words_per_epoch`
//! records, slot `i` of epoch `e` always hashing to the same word, with
//! slots dealt round-robin across the *global* worker set. The multiset of
//! records per epoch is therefore identical for every cluster shape, so a
//! 3-process run killed mid-flight and recovered into 2 processes must end
//! with exactly the counts of an unperturbed single-process run.
//!
//! Equality is checked through an order- and partition-independent digest:
//! each worker folds its owned `(word, final count)` pairs with XOR, and
//! per-worker digests XOR together into one cluster digest — XOR is
//! commutative, so how the words were partitioned (or which process
//! reports which share) cannot affect the combined value. The `ttd
//! recovery-demo` subcommand prints per-process digests and the
//! orchestrator combines them; the cluster integration tests combine
//! in-process.

use crate::config::Config;
use crate::dataflow::channels::{Data, Pact};
use crate::dataflow::input::InputSession;
use crate::dataflow::operator::OperatorExt;
use crate::dataflow::probe::{ProbeExt, ProbeHandle};
use crate::net::NetError;
use crate::nexmark::event::{Auction, Bid, Event};
use crate::nexmark::q4::closes_tokens;
use crate::recovery::{epoch_of, EpochSealed};
use crate::worker::execute::execute_cluster;
use crate::worker::Worker;
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Duration;

/// How far feeding may run ahead of the probed frontier. Bounding the lag
/// keeps the frontier (and with it checkpoint capture) advancing with the
/// feed instead of arbitrarily behind it.
const FEED_LAG: u64 = 4;

/// The deterministic workload's knobs.
#[derive(Clone, Copy)]
pub struct RecoveryDemoParams {
    /// Epochs to feed: `1..=epochs` (a recovered run replays only
    /// `resume + 1..=epochs`).
    pub epochs: u64,
    /// Records per epoch, across all workers.
    pub words_per_epoch: u64,
    /// Words are drawn from `0..vocab` — bounded, so steady-state count
    /// updates hit existing entries and stay allocation-free.
    pub vocab: u64,
    /// Extra sleep per epoch; widens the mid-run window a kill
    /// orchestrator (or a chaos schedule) aims at. Zero for tests.
    pub pacing: Duration,
    /// Fault injection: `(process, epoch)` — that process severs its net
    /// fabric (no drain, no goodbyes: a SIGKILL as peers observe it) when
    /// its feed reaches the epoch.
    pub crash_after: Option<(usize, u64)>,
}

impl Default for RecoveryDemoParams {
    fn default() -> Self {
        RecoveryDemoParams {
            epochs: 200,
            words_per_epoch: 64,
            vocab: 500,
            pacing: Duration::ZERO,
            crash_after: None,
        }
    }
}

/// One process's result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DemoOutcome {
    /// Ran to completion; the XOR digest over the final counts owned by
    /// this process's workers. XOR the per-process values for the cluster
    /// digest.
    Digest(u64),
    /// A peer process died abruptly; this process quiesced (typed
    /// [`NetError::PeerLost`], not a hang or a panic).
    PeerLost(usize),
    /// This process was the injected crash.
    Crashed,
}

/// What one worker thread hands back.
enum WorkerEnd {
    Digest(u64),
    PeerLost(usize),
    Crashed,
}

/// SplitMix64's finalizer: the demo's one hash, used both to draw words
/// and to fold `(word, count)` pairs into the digest.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58476d1ce4e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// The word at `(epoch, slot)` — a pure function, so any worker of any
/// cluster shape regenerates the identical stream.
pub fn demo_word(epoch: u64, slot: u64, vocab: u64) -> u64 {
    mix(epoch.wrapping_mul(0x1_0000_0000).wrapping_add(slot)) % vocab.max(1)
}

/// Folds one final `(word, count)` pair into a digest.
fn digest_entry(word: u64, count: u64) -> u64 {
    mix(word.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(count))
}

/// Runs the demo as cluster member `config.process_index` (or alone when
/// `config.processes <= 1`). Checkpointing and recovery follow the
/// config's `checkpoint_dir` / `checkpoint_interval` / `recover` fields.
pub fn run_recovery_demo(
    config: Config,
    params: RecoveryDemoParams,
) -> Result<DemoOutcome, NetError> {
    let shape = config.shape();
    let process = config.process_index;
    let base: usize = shape[..process].iter().sum();
    let results = execute_cluster::<u64, _, _>(config, move |worker| {
        drive(worker, params, process, base)
    })?;
    Ok(combine(results))
}

/// [`run_recovery_demo`] over NEXMark Q4's stage 1 (the token-held
/// data-dependent windows of §7.4) instead of a rolling count: the same
/// deterministic feed / crash / digest scheme, exercising checkpoint
/// capture and restore of auction state, re-minted expiry tokens, and the
/// category sums downstream of them.
pub fn run_q4_recovery_demo(
    config: Config,
    params: RecoveryDemoParams,
) -> Result<DemoOutcome, NetError> {
    let shape = config.shape();
    let process = config.process_index;
    let base: usize = shape[..process].iter().sum();
    let results = execute_cluster::<u64, _, _>(config, move |worker| {
        drive_q4(worker, params, process, base)
    })?;
    Ok(combine(results))
}

/// Folds one process's worker results into its outcome: an injected crash
/// dominates, then peer loss, else the XOR of the worker digests.
fn combine(results: Vec<WorkerEnd>) -> DemoOutcome {
    let mut digest = 0u64;
    let mut lost = None;
    let mut crashed = false;
    for end in results {
        match end {
            WorkerEnd::Digest(d) => digest ^= d,
            WorkerEnd::PeerLost(p) => lost = Some(p),
            WorkerEnd::Crashed => crashed = true,
        }
    }
    if crashed {
        DemoOutcome::Crashed
    } else if let Some(p) = lost {
        DemoOutcome::PeerLost(p)
    } else {
        DemoOutcome::Digest(digest)
    }
}

/// The per-worker build-and-feed loop.
fn drive(
    worker: &mut Worker<u64>,
    params: RecoveryDemoParams,
    process: usize,
    base: usize,
) -> WorkerEnd {
    let index = worker.index() as u64;
    let peers = worker.peers() as u64;
    let (mut input, stream) = worker.new_input::<u64>();
    let recovery = stream.scope().recovery();
    let logging = recovery.as_ref().is_some_and(|r| r.logging());

    // The counting cell lives outside the operator so the driver can read
    // the final counts (an operator emits *updates*; after a restore the
    // words untouched by replayed epochs would never re-emit).
    fn bump(counts: &mut HashMap<u64, u64>, word: &u64) -> u64 {
        let count = counts.entry(*word).or_insert(0);
        *count += 1;
        *count
    }
    let cell = Rc::new(RefCell::new(EpochSealed::new(
        HashMap::<u64, u64>::new(),
        bump as fn(&mut HashMap<u64, u64>, &u64) -> u64,
        logging,
    )));
    let counted = {
        let cell = cell.clone();
        let recovery = recovery.clone();
        stream.unary(Pact::exchange(|w: &u64| *w), "demo_counts", move |tok, _info| {
            drop(tok);
            if let Some(ctx) = &recovery {
                // Words route by value, so a restoring worker keeps
                // exactly the words the *new* shape assigns to it.
                ctx.register("demo_counts", cell.clone(), move |into, _old_worker, old| {
                    into.extend(old.into_iter().filter(|(w, _)| w % peers == index));
                });
            }
            let cell = cell.clone();
            move |input: &mut _, output: &mut _| {
                let mut cell = cell.borrow_mut();
                while let Some((token, data)) = input.next() {
                    let epoch = epoch_of(token.time());
                    let mut session = output.session(&token);
                    for word in data {
                        let count = cell.update(epoch, word);
                        session.give((word, count));
                    }
                }
            }
        })
    };
    let probe = counted.probe();
    let vocab = params.vocab;
    feed_and_finish(
        worker,
        &mut input,
        &probe,
        params,
        process,
        base,
        |input, epoch, slot| input.send(demo_word(epoch, slot, vocab)),
        || cell.borrow().state().iter().fold(0u64, |d, (w, c)| d ^ digest_entry(*w, *c)),
    )
}

/// The per-worker Q4 variant: feed deterministic NEXMark events through
/// stage 1 (token-held auction closes) into an externally readable
/// category-sums cell.
fn drive_q4(
    worker: &mut Worker<u64>,
    params: RecoveryDemoParams,
    process: usize,
    base: usize,
) -> WorkerEnd {
    let index = worker.index() as u64;
    let peers = worker.peers() as u64;
    let (mut input, stream) = worker.new_input::<Event>();
    let recovery = stream.scope().recovery();
    let logging = recovery.as_ref().is_some_and(|r| r.logging());
    let closes = closes_tokens(&stream);

    // Per-category (sum, count) of winning prices — the Q4 aggregate kept
    // outside the operator so the driver can digest the final state.
    fn fold_close(sums: &mut HashMap<u64, (u64, u64)>, update: &(u64, u64)) {
        let entry = sums.entry(update.0).or_insert((0, 0));
        entry.0 += update.1;
        entry.1 += 1;
    }
    let cell = Rc::new(RefCell::new(EpochSealed::new(
        HashMap::<u64, (u64, u64)>::new(),
        fold_close as fn(&mut HashMap<u64, (u64, u64)>, &(u64, u64)),
        logging,
    )));
    let summed = {
        let cell = cell.clone();
        let recovery = recovery.clone();
        closes.unary(
            Pact::exchange(|&(category, _): &(u64, u64)| category),
            "demo_q4_sums",
            move |tok, _info| {
                drop(tok);
                if let Some(ctx) = &recovery {
                    // Closes route by category, so a restoring worker keeps
                    // the categories the new shape assigns to it.
                    ctx.register("demo_q4_sums", cell.clone(), move |into, _old_worker, old| {
                        into.extend(old.into_iter().filter(|(c, _)| c % peers == index));
                    });
                }
                let cell = cell.clone();
                move |input: &mut _, output: &mut _| {
                    let mut cell = cell.borrow_mut();
                    while let Some((token, data)) = input.next() {
                        let epoch = epoch_of(token.time());
                        let mut session = output.session(&token);
                        for (category, price) in data {
                            cell.update(epoch, (category, price));
                            session.give(category);
                        }
                    }
                }
            },
        )
    };
    let probe = summed.probe();
    let words_per_epoch = params.words_per_epoch;
    feed_and_finish(
        worker,
        &mut input,
        &probe,
        params,
        process,
        base,
        |input, epoch, slot| input.send(demo_event(epoch, slot, words_per_epoch)),
        || {
            cell.borrow()
                .state()
                .iter()
                .fold(0u64, |d, (c, (s, n))| d ^ digest_entry(digest_entry(*c, *s), *n))
        },
    )
}

/// The event at `(epoch, slot)` — a pure function, like [`demo_word`].
/// Slots that are multiples of 3 open an auction expiring 1–4 epochs out;
/// the rest bid on an auction slot of this or the previous epoch. Bids
/// arriving at or after their auction's expiry are dropped by Q4 — on
/// every shape identically, since the drop depends only on event fields.
fn demo_event(epoch: u64, slot: u64, words_per_epoch: u64) -> Event {
    let r = mix(epoch.wrapping_mul(0x1_0000_0001).wrapping_add(slot));
    if slot % 3 == 0 {
        Event::Auction(Auction {
            id: epoch * words_per_epoch + slot,
            item: r % 1000,
            seller: r % 50,
            category: r % 8,
            initial_bid: 1,
            reserve: 1,
            date_time: epoch,
            expires: epoch + 1 + (r >> 8) % 4,
        })
    } else {
        let back = (r >> 4) % 2;
        let target_epoch = epoch.saturating_sub(back).max(1);
        let target_slot = ((r >> 16) % words_per_epoch) / 3 * 3;
        Event::Bid(Bid {
            auction: target_epoch * words_per_epoch + target_slot,
            bidder: r % 100,
            price: 1 + (r >> 24) % 1000,
            date_time: epoch,
        })
    }
}

/// The shared feed-and-drain loop behind both demo drivers: crash
/// injection, bounded-lag pacing, typed peer-loss detection, and the final
/// digest once the dataflow completes.
#[allow(clippy::too_many_arguments)]
fn feed_and_finish<D: Data>(
    worker: &mut Worker<u64>,
    input: &mut InputSession<u64, D>,
    probe: &ProbeHandle<u64>,
    params: RecoveryDemoParams,
    process: usize,
    base: usize,
    mut send_slot: impl FnMut(&mut InputSession<u64, D>, u64, u64),
    digest: impl FnOnce() -> u64,
) -> WorkerEnd {
    let index = worker.index() as u64;
    let peers = worker.peers() as u64;
    let crash_epoch = match params.crash_after {
        Some((p, epoch)) if p == process => Some(epoch),
        _ => None,
    };
    let resume = worker.resume_epoch();
    for epoch in resume + 1..=params.epochs {
        if crash_epoch == Some(epoch) {
            // The process's first worker severs the fabric (the crash);
            // its siblings just stop, as their threads would on SIGKILL.
            if worker.index() == base {
                worker.sever_net();
            } else {
                worker.poison();
            }
            return WorkerEnd::Crashed;
        }
        input.advance_to(epoch);
        let mut slot = index;
        while slot < params.words_per_epoch {
            send_slot(input, epoch, slot);
            slot += peers;
        }
        input.flush();
        // Keep processing within FEED_LAG epochs of the feed so the
        // frontier — and checkpoint capture — advances throughout the
        // run rather than in one burst at the end.
        while probe.less_than(&epoch.saturating_sub(FEED_LAG)) {
            if let Some(&p) = worker.lost_peers().first() {
                worker.poison();
                return WorkerEnd::PeerLost(p);
            }
            worker.step_or_park(Duration::from_micros(200));
        }
        if params.pacing > Duration::ZERO {
            std::thread::sleep(params.pacing);
        }
    }
    input.close();
    match worker.step_while_surviving(|| !probe.done()) {
        Ok(()) => WorkerEnd::Digest(digest()),
        Err(NetError::PeerLost { process }) => WorkerEnd::PeerLost(process),
        // Any other net error also means the run cannot complete; report
        // it like a loss with no attributable peer.
        Err(_) => WorkerEnd::PeerLost(usize::MAX),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_words_are_deterministic_and_bounded() {
        for epoch in 1..10 {
            for slot in 0..32 {
                let w = demo_word(epoch, slot, 100);
                assert!(w < 100);
                assert_eq!(w, demo_word(epoch, slot, 100));
            }
        }
    }

    #[test]
    fn single_process_digest_is_shape_independent() {
        let params = RecoveryDemoParams {
            epochs: 20,
            words_per_epoch: 32,
            vocab: 50,
            ..Default::default()
        };
        let digest_of = |workers: usize| {
            let config = Config { workers, pin_workers: false, ..Default::default() };
            match run_recovery_demo(config, params).expect("no net involved") {
                DemoOutcome::Digest(d) => d,
                other => panic!("unexpected outcome {other:?}"),
            }
        };
        let one = digest_of(1);
        assert_eq!(one, digest_of(2), "worker count must not change the digest");
        assert_eq!(one, digest_of(3), "worker count must not change the digest");
    }

    #[test]
    fn q4_digest_is_shape_independent_and_nonempty() {
        let params = RecoveryDemoParams {
            epochs: 20,
            words_per_epoch: 30,
            vocab: 50,
            ..Default::default()
        };
        let digest_of = |workers: usize| {
            let config = Config { workers, pin_workers: false, ..Default::default() };
            match run_q4_recovery_demo(config, params).expect("no net involved") {
                DemoOutcome::Digest(d) => d,
                other => panic!("unexpected outcome {other:?}"),
            }
        };
        let one = digest_of(1);
        assert_ne!(one, 0, "auctions must actually close (empty digest)");
        assert_eq!(one, digest_of(2), "worker count must not change the digest");
        assert_eq!(one, digest_of(3), "worker count must not change the digest");
    }
}
