//! Table formatting for the bench binaries: rows shaped like the paper's
//! tables (p50 / p999 / max in milliseconds, `DNF` for overload), plus the
//! per-worker fabric telemetry table (parks / unparks / ring-full stalls).

use super::histogram::fmt_ms;
use super::openloop::Outcome;
use crate::worker::allocator::WorkerTelemetry;

/// One table row: a configuration label and its outcome.
pub struct Row {
    /// Configuration cells (e.g. rate, workers, quantum, mechanism).
    pub cells: Vec<String>,
    /// The measured outcome.
    pub outcome: Outcome,
}

/// Formats the latency triple of an outcome the way Figure 9 does.
pub fn latency_cells(outcome: &Outcome) -> [String; 3] {
    match outcome {
        Outcome::Dnf => ["DNF".into(), "DNF".into(), "DNF".into()],
        Outcome::Completed { histogram, .. } => [
            fmt_ms(histogram.p50()),
            fmt_ms(histogram.p999()),
            fmt_ms(histogram.max()),
        ],
    }
}

/// Formats per-worker fabric telemetry as table rows.
pub fn telemetry_rows(telemetry: &[WorkerTelemetry]) -> Vec<Vec<String>> {
    telemetry
        .iter()
        .map(|t| {
            vec![
                t.worker.to_string(),
                t.parks.to_string(),
                t.unparks.to_string(),
                t.ring_full_stalls.to_string(),
            ]
        })
        .collect()
}

/// Prints the per-worker parking / backpressure telemetry of a completed
/// run (no-op for an empty snapshot, e.g. from old outcomes).
pub fn print_worker_telemetry(telemetry: &[WorkerTelemetry]) {
    if telemetry.is_empty() {
        return;
    }
    print_table(
        "worker telemetry",
        &["worker", "parks", "unparks", "ring-full stalls"],
        &telemetry_rows(telemetry),
    );
}

/// Prints a table with a header; column widths auto-fit.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::LatencyHistogram;

    #[test]
    fn dnf_rows_say_dnf() {
        let cells = latency_cells(&Outcome::Dnf);
        assert_eq!(cells, ["DNF", "DNF", "DNF"]);
    }

    #[test]
    fn completed_rows_are_milliseconds() {
        let mut h = LatencyHistogram::new();
        h.record(1_500_000);
        let cells = latency_cells(&Outcome::Completed {
            histogram: h,
            achieved_rate: 0.0,
            telemetry: Vec::new(),
        });
        assert_eq!(cells[0], "1.50");
    }

    #[test]
    fn telemetry_rows_format() {
        let rows = telemetry_rows(&[WorkerTelemetry {
            worker: 3,
            parks: 10,
            unparks: 7,
            ring_full_stalls: 2,
        }]);
        let want: Vec<Vec<String>> =
            vec![["3", "10", "7", "2"].iter().map(|s| s.to_string()).collect()];
        assert_eq!(rows, want);
    }
}
