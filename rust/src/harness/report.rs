//! Table formatting for the bench binaries: rows shaped like the paper's
//! tables (p50 / p999 / max in milliseconds, `DNF` for overload).

use super::histogram::fmt_ms;
use super::openloop::Outcome;

/// One table row: a configuration label and its outcome.
pub struct Row {
    /// Configuration cells (e.g. rate, workers, quantum, mechanism).
    pub cells: Vec<String>,
    /// The measured outcome.
    pub outcome: Outcome,
}

/// Formats the latency triple of an outcome the way Figure 9 does.
pub fn latency_cells(outcome: &Outcome) -> [String; 3] {
    match outcome {
        Outcome::Dnf => ["DNF".into(), "DNF".into(), "DNF".into()],
        Outcome::Completed { histogram, .. } => [
            fmt_ms(histogram.p50()),
            fmt_ms(histogram.p999()),
            fmt_ms(histogram.max()),
        ],
    }
}

/// Prints a table with a header; column widths auto-fit.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::LatencyHistogram;

    #[test]
    fn dnf_rows_say_dnf() {
        let cells = latency_cells(&Outcome::Dnf);
        assert_eq!(cells, ["DNF", "DNF", "DNF"]);
    }

    #[test]
    fn completed_rows_are_milliseconds() {
        let mut h = LatencyHistogram::new();
        h.record(1_500_000);
        let cells = latency_cells(&Outcome::Completed { histogram: h, achieved_rate: 0.0 });
        assert_eq!(cells[0], "1.50");
    }
}
