//! Table formatting for the bench binaries: rows shaped like the paper's
//! tables (p50 / p999 / max in milliseconds, `DNF` for overload), plus the
//! fabric telemetry table — per-worker parks / unparks / ring-full stalls
//! and the net-plane counters (frames and bytes sent/received, send-queue
//! stalls), grouped by process with per-process aggregate rows.

use super::histogram::fmt_ms;
use super::openloop::Outcome;
use crate::worker::allocator::WorkerTelemetry;
use std::collections::BTreeMap;

/// One table row: a configuration label and its outcome.
pub struct Row {
    /// Configuration cells (e.g. rate, workers, quantum, mechanism).
    pub cells: Vec<String>,
    /// The measured outcome.
    pub outcome: Outcome,
}

/// Formats the latency triple of an outcome the way Figure 9 does.
pub fn latency_cells(outcome: &Outcome) -> [String; 3] {
    match outcome {
        Outcome::Dnf => ["DNF".into(), "DNF".into(), "DNF".into()],
        Outcome::Completed { histogram, .. } => [
            fmt_ms(histogram.p50()),
            fmt_ms(histogram.p999()),
            fmt_ms(histogram.max()),
        ],
    }
}

/// Column headers of the telemetry table (shared by the per-worker and
/// per-process aggregate rows). The `prog-*` columns surface the
/// broadcast-dedup progress plane: `prog-frames-tx` counts one physical
/// frame per (flush, remote process), and `prog-fanout` counts logical
/// deliveries — their ratio is the destination process's worker count
/// when dedup is engaged. The reactor columns are process-wide (the one
/// I/O thread's counters, reported on each process's worker 0):
/// `net-polls` counts reactor wakeups (readiness returns and futex
/// wakes; with infinite-timeout sleeping every count is a real wake),
/// the `spur-*` trio splits wakeups whose following pass moved nothing
/// by cause (a doorbell byte with an empty ring, the self-wake pipe or
/// futex bump with nothing queued, a readable data descriptor that
/// yielded no frame bytes), `net-partial-wr` counts short writes
/// (socket buffer full), `net-shm-full` counts shm-ring-full stalls,
/// and `ring-resizes` / `cadence-adj` count governor decisions applied
/// (live shm-ring grows and progress-flush cadence changes).
/// `gov-prog-frames` is the governor's conservation ledger (progress
/// frames its sampling epochs observed; equals `prog-frames-tx` summed
/// over the process after an orderly autotuned shutdown). `peer-lost`
/// counts peer processes whose stream ended without the orderly
/// goodbye — abrupt deaths the recovery machinery restarts from a
/// checkpoint for; zero on clean runs.
pub const TELEMETRY_HEADER: [&str; 23] = [
    "process",
    "worker",
    "parks",
    "unparks",
    "ring-full",
    "net-frames-tx",
    "net-frames-rx",
    "net-bytes-tx",
    "net-bytes-rx",
    "send-stalls",
    "prog-frames-tx",
    "prog-frames-rx",
    "prog-fanout",
    "net-polls",
    "spur-bell",
    "spur-waker",
    "spur-empty",
    "net-partial-wr",
    "net-shm-full",
    "ring-resizes",
    "cadence-adj",
    "gov-prog-frames",
    "peer-lost",
];

/// The one structured view of a worker's counters that every rendering
/// derives from: the human table rows below and the `--metrics` JSONL
/// snapshots ([`crate::observe::metrics`]) both iterate this array, so
/// a counter added here shows up everywhere under one name.
pub fn telemetry_fields(t: &WorkerTelemetry) -> [(&'static str, u64); 21] {
    [
        ("parks", t.parks),
        ("unparks", t.unparks),
        ("ring-full", t.ring_full_stalls),
        ("net-frames-tx", t.net.frames_sent),
        ("net-frames-rx", t.net.frames_recv),
        ("net-bytes-tx", t.net.bytes_sent),
        ("net-bytes-rx", t.net.bytes_recv),
        ("send-stalls", t.net.send_queue_stalls),
        ("prog-frames-tx", t.net.progress_frames_sent),
        ("prog-frames-rx", t.net.progress_frames_recv),
        ("prog-fanout", t.net.progress_batches_recv),
        ("net-polls", t.net.poll_wakeups),
        ("spur-bell", t.net.spurious_doorbell),
        ("spur-waker", t.net.spurious_waker),
        ("spur-empty", t.net.spurious_pollin_empty),
        ("net-partial-wr", t.net.partial_writes),
        ("net-shm-full", t.net.shm_full_stalls),
        ("ring-resizes", t.net.ring_resizes),
        ("cadence-adj", t.net.cadence_adjusts),
        ("gov-prog-frames", t.net.governor_progress_frames),
        ("peer-lost", t.net.peer_lost),
    ]
}

fn telemetry_row(process: &str, worker: &str, t: &WorkerTelemetry) -> Vec<String> {
    let mut row = vec![process.to_string(), worker.to_string()];
    row.extend(telemetry_fields(t).iter().map(|(_, v)| v.to_string()));
    row
}

/// Sums a group of workers' counters into one aggregate entry.
fn aggregate(workers: &[&WorkerTelemetry]) -> WorkerTelemetry {
    let mut total = WorkerTelemetry::default();
    for t in workers {
        total.parks += t.parks;
        total.unparks += t.unparks;
        total.ring_full_stalls += t.ring_full_stalls;
        total.net.frames_sent += t.net.frames_sent;
        total.net.frames_recv += t.net.frames_recv;
        total.net.bytes_sent += t.net.bytes_sent;
        total.net.bytes_recv += t.net.bytes_recv;
        total.net.send_queue_stalls += t.net.send_queue_stalls;
        total.net.progress_frames_sent += t.net.progress_frames_sent;
        total.net.progress_bytes_sent += t.net.progress_bytes_sent;
        total.net.progress_frames_recv += t.net.progress_frames_recv;
        total.net.progress_batches_recv += t.net.progress_batches_recv;
        total.net.poll_wakeups += t.net.poll_wakeups;
        total.net.spurious_doorbell += t.net.spurious_doorbell;
        total.net.spurious_waker += t.net.spurious_waker;
        total.net.spurious_pollin_empty += t.net.spurious_pollin_empty;
        total.net.partial_writes += t.net.partial_writes;
        total.net.shm_full_stalls += t.net.shm_full_stalls;
        total.net.kernel_frame_bytes_tx += t.net.kernel_frame_bytes_tx;
        total.net.ring_resizes += t.net.ring_resizes;
        total.net.cadence_adjusts += t.net.cadence_adjusts;
        total.net.governor_progress_frames += t.net.governor_progress_frames;
        total.net.peer_lost += t.net.peer_lost;
    }
    total
}

/// Formats fabric telemetry grouped by process: each process's workers in
/// index order, followed by a `Σ` aggregate row for that process.
pub fn telemetry_rows(telemetry: &[WorkerTelemetry]) -> Vec<Vec<String>> {
    let mut by_process: BTreeMap<usize, Vec<&WorkerTelemetry>> = BTreeMap::new();
    for t in telemetry {
        by_process.entry(t.process).or_default().push(t);
    }
    let multi = by_process.len() > 1 || telemetry.iter().any(|t| t.process != 0);
    let mut rows = Vec::new();
    for (process, workers) in &by_process {
        for t in workers {
            rows.push(telemetry_row(&process.to_string(), &t.worker.to_string(), t));
        }
        // The aggregate row only earns its ink when there is more than one
        // group (or more than one worker) to aggregate over.
        if multi || workers.len() > 1 {
            let total = aggregate(workers);
            rows.push(telemetry_row(&process.to_string(), "Σ", &total));
        }
    }
    rows
}

/// Prints the parking / backpressure / net telemetry of a completed run,
/// grouped by process (no-op for an empty snapshot, e.g. from old
/// outcomes).
pub fn print_worker_telemetry(telemetry: &[WorkerTelemetry]) {
    if telemetry.is_empty() {
        return;
    }
    print_table("worker telemetry", &TELEMETRY_HEADER, &telemetry_rows(telemetry));
}

/// Prints a table with a header; column widths auto-fit.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Prints the per-epoch frontier-latency attribution of a traced run:
/// per-worker lifetime totals (where each worker's epoch wall time
/// went — operators, progress propagation, parking, checkpoints) and
/// the slowest epochs by frontier latency (the run's critical path).
/// No-op when the trace saw no closed epochs.
pub fn print_epoch_attribution(report: &crate::observe::TraceReport) {
    let totals: Vec<_> = report.totals.iter().filter(|t| t.epochs > 0).collect();
    if totals.is_empty() {
        return;
    }
    let pct = |part: u64, whole: u64| {
        if whole == 0 {
            "-".to_string()
        } else {
            format!("{:.1}%", part as f64 * 100.0 / whole as f64)
        }
    };
    let rows: Vec<Vec<String>> = totals
        .iter()
        .map(|t| {
            vec![
                t.worker.to_string(),
                t.epochs.to_string(),
                if t.measured > 0 { fmt_ms(t.latency_sum_ns / t.measured) } else { "-".into() },
                if t.measured > 0 { fmt_ms(t.latency_max_ns) } else { "-".into() },
                pct(t.op_ns, t.wall_ns),
                pct(t.progress_ns, t.wall_ns),
                pct(t.park_ns, t.wall_ns),
                pct(t.checkpoint_ns, t.wall_ns),
                t.records_in.to_string(),
                t.records_out.to_string(),
            ]
        })
        .collect();
    print_table(
        "frontier-latency attribution (per worker)",
        &[
            "worker", "epochs", "lat-avg", "lat-max", "op", "progress", "park", "ckpt", "in",
            "out",
        ],
        &rows,
    );
    let worst: Vec<Vec<String>> = report
        .worst
        .iter()
        .filter(|s| s.latency_ns.is_some())
        .take(8)
        .map(|s| {
            vec![
                s.worker.to_string(),
                s.epoch.to_string(),
                fmt_ms(s.latency_ns.unwrap_or(0)),
                fmt_ms(s.wall_ns),
                fmt_ms(s.op_ns),
                fmt_ms(s.progress_ns),
                fmt_ms(s.park_ns),
                s.top_op.map_or("-".into(), |(op, ns)| format!("{op}:{}", fmt_ms(ns))),
            ]
        })
        .collect();
    if !worst.is_empty() {
        print_table(
            "slowest epochs (critical path, ms)",
            &["worker", "epoch", "latency", "wall", "op", "progress", "park", "top-op"],
            &worst,
        );
    }
    if report.dropped > 0 {
        println!("(trace rings dropped {} events under load)", report.dropped);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::LatencyHistogram;

    #[test]
    fn telemetry_header_and_fields_stay_aligned() {
        let fields = telemetry_fields(&WorkerTelemetry::default());
        assert_eq!(TELEMETRY_HEADER.len(), 2 + fields.len());
        for (i, (name, _)) in fields.iter().enumerate() {
            assert_eq!(TELEMETRY_HEADER[2 + i], *name, "column {i} drifted");
        }
    }

    #[test]
    fn dnf_rows_say_dnf() {
        let cells = latency_cells(&Outcome::Dnf);
        assert_eq!(cells, ["DNF", "DNF", "DNF"]);
    }

    #[test]
    fn completed_rows_are_milliseconds() {
        let mut h = LatencyHistogram::new();
        h.record(1_500_000);
        let cells = latency_cells(&Outcome::Completed {
            histogram: h,
            achieved_rate: 0.0,
            telemetry: Vec::new(),
        });
        assert_eq!(cells[0], "1.50");
    }

    #[test]
    fn telemetry_rows_format() {
        let rows = telemetry_rows(&[WorkerTelemetry {
            worker: 3,
            process: 0,
            parks: 10,
            unparks: 7,
            ring_full_stalls: 2,
            net: Default::default(),
        }]);
        // One worker, one process: no aggregate row.
        let want: Vec<Vec<String>> = vec![[
            "0", "3", "10", "7", "2", "0", "0", "0", "0", "0", "0", "0", "0", "0", "0", "0", "0",
            "0", "0", "0", "0", "0", "0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect()];
        assert_eq!(rows, want);
    }

    #[test]
    fn telemetry_groups_by_process_with_aggregates() {
        let mut w0 = WorkerTelemetry { worker: 0, process: 0, parks: 1, ..Default::default() };
        w0.net.frames_sent = 5;
        w0.net.progress_frames_sent = 2;
        let mut w1 = WorkerTelemetry { worker: 1, process: 0, parks: 2, ..Default::default() };
        w1.net.frames_sent = 7;
        w1.net.progress_batches_recv = 3;
        let mut w2 = WorkerTelemetry { worker: 2, process: 1, parks: 4, ..Default::default() };
        w2.net.bytes_recv = 100;
        w2.net.poll_wakeups = 9;
        w2.net.shm_full_stalls = 4;
        let rows = telemetry_rows(&[w0, w1, w2]);
        // 3 worker rows + 2 per-process aggregate rows, grouped: process 0
        // (workers 0, 1, Σ), then process 1 (worker 2, Σ).
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[2][1], "Σ");
        assert_eq!(rows[2][2], "3", "parks aggregate");
        assert_eq!(rows[2][5], "12", "frames-tx aggregate");
        assert_eq!(rows[2][10], "2", "prog-frames-tx aggregate");
        assert_eq!(rows[2][12], "3", "prog-fanout aggregate");
        assert_eq!(rows[3][0], "1");
        assert_eq!(rows[4][1], "Σ");
        assert_eq!(rows[4][8], "100", "bytes-rx aggregate");
        assert_eq!(rows[4][13], "9", "net-polls aggregate");
        assert_eq!(rows[4][18], "4", "net-shm-full aggregate");
    }
}
