//! The open-loop evaluation harness (paper §7.1).
//!
//! "Our open-loop testing harness supplies the input at a specified rate,
//! even if the system itself becomes less responsive. We record the
//! observed latency in units of nanoseconds in a histogram of
//! logarithmically-sized bins. If the system becomes overloaded and
//! end-to-end latency becomes greater than 1 second, the testing harness
//! regards the experiment as failed [DNF]."
//!
//! Timestamps are wall-clock nanoseconds since the experiment epoch,
//! quantized to the configured power-of-two quantum (§7.2): a quantum of
//! `2^x` ns admits at most `1e9 / 2^x` distinct timestamps per second. A
//! stamp `t` completes when the sink proves no more data `≤ t` can arrive;
//! its latency is `completion_wall_time - t`.

use super::histogram::LatencyHistogram;
use super::workloads::{build_noop_chain, build_word_count, CompletionProbe, WorkloadInput};
use crate::config::Config;
use crate::coordination::Mechanism;
use crate::net::NetError;
use crate::worker::allocator::WorkerTelemetry;
use crate::worker::execute::{execute, execute_cluster};
use crate::worker::Worker;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Which benchmark dataflow to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// §7.2 word count: data at `rate_per_worker` tuples/s.
    WordCount,
    /// §7.3 idle pipeline of `n` no-ops: timestamp ticks only, no data.
    NoopChain(usize),
}

/// Open-loop experiment parameters.
#[derive(Clone, Copy, Debug)]
pub struct Params {
    /// Worker threads.
    pub workers: usize,
    /// Coordination mechanism under test.
    pub mechanism: Mechanism,
    /// Benchmark dataflow.
    pub workload: Workload,
    /// Offered load per worker: tuples/s (word count) — ignored for no-op
    /// chains, whose load is set by `quantum_ns` (ticks/s = 1e9 / quantum).
    pub rate_per_worker: u64,
    /// Timestamp quantum in nanoseconds (power of two for word count; for
    /// no-op chains this is the tick period).
    pub quantum_ns: u64,
    /// Measured duration.
    pub duration: Duration,
    /// Warm-up (latencies not recorded).
    pub warmup: Duration,
    /// Distinct words fed to the word count.
    pub vocab: u64,
    /// Latency above which the experiment is declared failed.
    pub dnf_after: Duration,
    /// Pin workers to cores.
    pub pin_workers: bool,
}

impl Params {
    /// Paper-like defaults (scaled to this testbed); see the bench binaries
    /// for the per-figure sweeps.
    pub fn new(mechanism: Mechanism, workload: Workload) -> Self {
        Params {
            workers: 4,
            mechanism,
            workload,
            rate_per_worker: 250_000,
            quantum_ns: 1 << 13,
            duration: Duration::from_secs(2),
            warmup: Duration::from_millis(500),
            vocab: 1 << 14,
            dnf_after: Duration::from_secs(1),
            pin_workers: true,
        }
    }
}

/// The outcome of one experiment.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// Completed within the latency bound.
    Completed {
        /// Merged latency histogram across workers.
        histogram: LatencyHistogram,
        /// Tuples/s actually offered (all workers).
        achieved_rate: f64,
        /// Per-worker fabric telemetry (parks, unparks, ring-full stalls).
        telemetry: Vec<WorkerTelemetry>,
    },
    /// Overloaded: end-to-end latency exceeded the bound (paper: "DNF").
    Dnf,
}

impl Outcome {
    /// True iff the experiment failed.
    pub fn is_dnf(&self) -> bool {
        matches!(self, Outcome::Dnf)
    }
}

/// Per-worker driver result.
enum WorkerOutcome {
    Completed { histogram: LatencyHistogram, sent: u64, telemetry: WorkerTelemetry },
    Dnf,
}

/// Merges per-worker outcomes into the experiment outcome.
fn collect(results: Vec<WorkerOutcome>, duration: Duration) -> Outcome {
    let mut histogram = LatencyHistogram::new();
    let mut sent_total = 0u64;
    let mut telemetry = Vec::new();
    for result in results {
        match result {
            WorkerOutcome::Dnf => return Outcome::Dnf,
            WorkerOutcome::Completed { histogram: h, sent, telemetry: t } => {
                histogram.merge(&h);
                sent_total += sent;
                telemetry.push(t);
            }
        }
    }
    let achieved_rate = sent_total as f64 / duration.as_secs_f64();
    Outcome::Completed { histogram, achieved_rate, telemetry }
}

/// Runs one open-loop experiment.
pub fn run(params: Params) -> Outcome {
    run_observed(params, crate::config::ObserveOptions::default())
}

/// [`run`] with event tracing / metrics export (`Params` is `Copy`, so
/// the non-`Copy` output paths ride separately).
pub fn run_observed(params: Params, observe: crate::config::ObserveOptions) -> Outcome {
    let epoch = Instant::now() + Duration::from_millis(50); // build headroom
    let config = Config {
        workers: params.workers,
        pin_workers: params.pin_workers,
        trace_path: observe.trace_path,
        metrics_path: observe.metrics_path,
        ..Config::default()
    };
    let results = execute::<u64, _, _>(config, move |worker| drive(worker, params, epoch));
    collect(results, params.duration)
}

/// Runs this process's share of a multi-process experiment (every process
/// calls this with the same `params` and its own index; `params.workers`
/// counts workers *per process*). The outcome merges only the local
/// workers' histograms and telemetry — each process reports its own.
///
/// Timestamps are wall-clock nanoseconds from a per-process epoch taken
/// *after* the cluster bootstrap completes, so cross-process epoch skew is
/// bounded by connection time on the cluster's network (microseconds on
/// loopback) — far under the DNF bound the harness enforces.
pub fn run_cluster(
    params: Params,
    processes: usize,
    process_index: usize,
    addresses: Vec<String>,
    net: crate::config::NetOptions,
) -> Result<Outcome, NetError> {
    run_cluster_observed(
        params,
        processes,
        process_index,
        addresses,
        net,
        crate::config::ObserveOptions::default(),
    )
}

/// [`run_cluster`] with event tracing / metrics export. Only process 0's
/// paths matter: the bootstrap handshake propagates them cluster-wide,
/// and each process writes `<stem>.p<I>.<ext>`.
pub fn run_cluster_observed(
    params: Params,
    processes: usize,
    process_index: usize,
    addresses: Vec<String>,
    net: crate::config::NetOptions,
    observe: crate::config::ObserveOptions,
) -> Result<Outcome, NetError> {
    let config = Config {
        workers: params.workers,
        pin_workers: params.pin_workers,
        processes,
        process_index,
        addresses,
        net_transport: net.transport,
        reactor_backend: net.reactor,
        parking: net.parking,
        autotune: net.autotune,
        trace_path: observe.trace_path,
        metrics_path: observe.metrics_path,
        ..Config::default()
    };
    // The epoch must postdate the bootstrap handshake (which can take
    // arbitrarily long while peers start up), so each worker takes it
    // lazily on first use — the OnceLock is set by whichever local worker
    // arrives first, after `execute_cluster` has connected the mesh.
    let epoch_cell = std::sync::OnceLock::new();
    let results = execute_cluster::<u64, _, _>(config, move |worker| {
        let epoch = *epoch_cell.get_or_init(|| Instant::now() + Duration::from_millis(50));
        drive(worker, params, epoch)
    })?;
    Ok(collect(results, params.duration))
}

/// The per-worker open-loop driving loop.
fn drive(worker: &mut Worker<u64>, params: Params, epoch: Instant) -> WorkerOutcome {
    let (mut input, probe) = match params.workload {
        Workload::WordCount => build_word_count(worker, params.mechanism),
        Workload::NoopChain(n) => build_noop_chain(worker, params.mechanism, n),
    };
    worker.finalize();

    let quantum = params.quantum_ns.max(1);
    let data_rate = match params.workload {
        Workload::WordCount => params.rate_per_worker,
        Workload::NoopChain(_) => 0,
    };
    let warmup_ns = params.warmup.as_nanos() as u64;
    let total_ns = (params.warmup + params.duration).as_nanos() as u64;
    let dnf_ns = params.dnf_after.as_nanos() as u64;

    // Deterministic per-worker word generator (xorshift64*).
    let mut rng_state = 0x9e3779b97f4a7c15u64 ^ ((worker.index() as u64 + 1) << 32);
    let mut next_word = move || {
        rng_state ^= rng_state << 13;
        rng_state ^= rng_state >> 7;
        rng_state ^= rng_state << 17;
        rng_state.wrapping_mul(0x2545f4914f6cdd1d)
    };

    let mut histogram = LatencyHistogram::new();
    let mut pending: VecDeque<u64> = VecDeque::new();
    let mut sent = 0u64;
    let mut measured_sent = 0u64;
    let mut last_quantum = 0u64;

    // Wait for the shared epoch so workers agree on wall-clock stamps.
    while Instant::now() < epoch {
        std::thread::yield_now();
    }

    let mut dnf = false;
    loop {
        let now = epoch.elapsed().as_nanos() as u64;
        if now >= total_ns {
            break;
        }

        // Walk EVERY quantum boundary up to `now` — never skip one. The
        // old code jumped straight to `now / quantum * quantum`, so a
        // stall in `worker.step()` collapsed all the boundaries it slept
        // through into a single `pending` stamp: the skipped quanta were
        // never measured and the stall vanished from the histogram
        // (coordinated omission). Here each elapsed quantum first gets
        // its due data backfilled at the quantum's own stamp, then enters
        // `pending` with its absolute schedule time, so a stalled system
        // is charged the full latency of every quantum it delayed.
        loop {
            let q = last_quantum.saturating_add(quantum);
            if q > now {
                break;
            }
            if data_rate > 0 {
                let target = (q as u128 * data_rate as u128 / 1_000_000_000) as u64;
                let due = target.saturating_sub(sent);
                for _ in 0..due {
                    input.send(last_quantum, next_word() % params.vocab);
                }
                sent += due;
                if q >= warmup_ns {
                    measured_sent += due;
                }
            }
            input.advance(q);
            pending.push_back(q);
            last_quantum = q;
        }
        // Residual data due within the currently open quantum.
        if data_rate > 0 {
            let target = (now as u128 * data_rate as u128 / 1_000_000_000) as u64;
            let due = target.saturating_sub(sent);
            for _ in 0..due {
                input.send(last_quantum, next_word() % params.vocab);
            }
            sent += due;
            if now >= warmup_ns {
                measured_sent += due;
            }
        }

        worker.step();

        // Retire completed stamps; check the overload bound on the oldest.
        let now2 = epoch.elapsed().as_nanos() as u64;
        while let Some(&oldest) = pending.front() {
            if probe.complete(oldest) {
                if oldest >= warmup_ns {
                    histogram.record(now2.saturating_sub(oldest));
                }
                pending.pop_front();
            } else {
                if now2.saturating_sub(oldest) > dnf_ns {
                    // Overloaded. Do NOT stop stepping: peers depend on
                    // this worker's operator instances to drain their own
                    // dataflow — fall through to cooperative teardown.
                    dnf = true;
                }
                break;
            }
        }
        if dnf {
            break;
        }
    }

    // Cooperative teardown: close the input and KEEP STEPPING until the
    // whole dataflow drains (bounded by a hard deadline so an engine bug
    // surfaces as DNF, never as a hang). Remaining stamps still count
    // toward the histogram and the DNF verdict.
    input.close();
    let teardown_deadline =
        Instant::now() + params.dnf_after + Duration::from_secs(5);
    while !probe.done() {
        worker.step();
        let now = epoch.elapsed().as_nanos() as u64;
        while let Some(&oldest) = pending.front() {
            if probe.complete(oldest) {
                if oldest >= warmup_ns {
                    histogram.record(now.saturating_sub(oldest));
                }
                pending.pop_front();
            } else {
                if now.saturating_sub(oldest) > dnf_ns {
                    dnf = true;
                    pending.pop_front();
                }
                break;
            }
        }
        if Instant::now() > teardown_deadline {
            dnf = true;
            break;
        }
    }
    if dnf || !pending.is_empty() {
        return WorkerOutcome::Dnf;
    }
    WorkerOutcome::Completed { histogram, sent: measured_sent, telemetry: worker.telemetry() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_word_count_completes_at_modest_load() {
        let mut params = Params::new(Mechanism::Tokens, Workload::WordCount);
        params.workers = 2;
        params.pin_workers = false;
        params.rate_per_worker = 20_000;
        params.quantum_ns = 1 << 16;
        params.duration = Duration::from_millis(400);
        params.warmup = Duration::from_millis(100);
        match run(params) {
            Outcome::Completed { histogram, achieved_rate, telemetry } => {
                assert!(histogram.count() > 0, "no latencies recorded");
                assert!(achieved_rate > 10_000.0, "rate {achieved_rate}");
                // Sane latencies: under the DNF bound by construction.
                assert!(histogram.max() < 1_000_000_000);
                assert_eq!(telemetry.len(), 2, "one telemetry row per worker");
            }
            Outcome::Dnf => panic!("DNF at trivial load"),
        }
    }

    #[test]
    fn open_loop_accounts_every_quantum_and_the_offered_rate() {
        // Offered-rate accounting: the harness must (a) achieve the
        // offered rate it reports against, and (b) enter EVERY quantum
        // boundary into the pending queue — a harness that skips quanta
        // under-counts the histogram and masks stalls (coordinated
        // omission). The histogram count is the witness: each measured
        // quantum records exactly one latency.
        let mut params = Params::new(Mechanism::Tokens, Workload::WordCount);
        params.workers = 2;
        params.pin_workers = false;
        params.rate_per_worker = 50_000;
        params.quantum_ns = 1 << 17; // ~131 us
        params.duration = Duration::from_millis(400);
        params.warmup = Duration::from_millis(100);
        match run(params) {
            Outcome::Completed { histogram, achieved_rate, .. } => {
                let offered = params.workers as f64 * params.rate_per_worker as f64;
                let err = (achieved_rate - offered).abs() / offered;
                assert!(err < 0.15, "achieved {achieved_rate} vs offered {offered}");
                // One histogram entry per measured quantum per worker.
                let per_worker =
                    params.duration.as_nanos() as u64 / params.quantum_ns;
                let expected = per_worker * params.workers as u64;
                assert!(
                    histogram.count() >= expected * 8 / 10,
                    "quanta skipped: {} recorded, ~{} scheduled",
                    histogram.count(),
                    expected
                );
                assert!(
                    histogram.count() <= expected + 8 * params.workers as u64,
                    "over-counted: {} recorded, ~{} scheduled",
                    histogram.count(),
                    expected
                );
            }
            Outcome::Dnf => panic!("DNF at modest load"),
        }
    }

    #[test]
    fn noop_chain_all_mechanisms_complete_at_low_tick_rate() {
        for mechanism in Mechanism::all() {
            let mut params = Params::new(mechanism, Workload::NoopChain(8));
            params.workers = 2;
            params.pin_workers = false;
            params.quantum_ns = 1_000_000; // 1k ticks/s
            params.duration = Duration::from_millis(300);
            params.warmup = Duration::from_millis(100);
            let outcome = run(params);
            assert!(!outcome.is_dnf(), "{mechanism:?} DNF at 1k ticks/s");
        }
    }
}
