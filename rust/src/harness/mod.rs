//! The paper's evaluation harness: open-loop load generation, log-binned
//! latency histograms, DNF detection, and the benchmark workloads (§7.1).

pub mod histogram;
pub mod openloop;
pub mod pacer;
pub mod recovery_demo;
pub mod report;
pub mod workloads;

pub use histogram::LatencyHistogram;
pub use openloop::{run, Outcome, Params, Workload};
pub use pacer::Pacer;
pub use recovery_demo::{run_q4_recovery_demo, run_recovery_demo, DemoOutcome, RecoveryDemoParams};
pub use workloads::{CompletionProbe, WorkloadInput};
