//! Log-binned latency histograms (paper §7.1: "we record the observed
//! latency in units of nanoseconds in a histogram of logarithmically-sized
//! bins").
//!
//! HDR-style binning: values are grouped by magnitude (the position of the
//! highest set bit) with 16 linear sub-buckets per magnitude, giving a
//! worst-case quantization error of 1/16 ≈ 6% — ample for reporting p50 /
//! p999 / max as the paper does.

/// Linear sub-buckets per power of two (log2).
const SUB_BITS: u32 = 4;
const SUB_BUCKETS: u64 = 1 << SUB_BITS;

/// A latency histogram over `u64` nanosecond values.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    max: u64,
    min: u64,
    sum: u128,
}

fn bucket_index(value: u64) -> usize {
    let v = value | 1;
    let magnitude = 63 - v.leading_zeros();
    if magnitude < SUB_BITS {
        value as usize
    } else {
        let shift = magnitude - SUB_BITS;
        (((magnitude - SUB_BITS + 1) as u64 * SUB_BUCKETS) as usize) + ((v >> shift) as usize
            - SUB_BUCKETS as usize)
    }
}

/// Upper bound of the bucket with the given index (inverse of
/// `bucket_index`, up to quantization).
fn bucket_upper(index: usize) -> u64 {
    if index < SUB_BUCKETS as usize {
        index as u64
    } else {
        let index = index as u64 - SUB_BUCKETS;
        let magnitude = index / SUB_BUCKETS;
        let sub = index % SUB_BUCKETS;
        ((SUB_BUCKETS + sub + 1) << magnitude) - 1
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; bucket_index(u64::MAX) + 1],
            total: 0,
            max: 0,
            min: u64::MAX,
            sum: 0,
        }
    }

    /// Records one value.
    #[inline]
    pub fn record(&mut self, value: u64) {
        self.counts[bucket_index(value)] += 1;
        self.total += 1;
        self.sum += value as u128;
        if value > self.max {
            self.max = value;
        }
        if value < self.min {
            self.min = value;
        }
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact maximum recorded value.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact minimum recorded value (0 if empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Mean of recorded values.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Value at quantile `q` in `[0, 1]` (bucket upper bound; exact max for
    /// the top).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper(i).min(self.max);
            }
        }
        self.max
    }

    /// Median.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 99.9th percentile (the paper's tail metric).
    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// Merges another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Formats nanoseconds the way the paper's tables do (milliseconds with two
/// decimals).
pub fn fmt_ms(ns: u64) -> String {
    format!("{:.2}", ns as f64 / 1e6)
}

/// Formats nanoseconds adaptively (µs / ms / s) for plots and logs.
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_roundtrip_error_bounded() {
        // Quantization error of the (index -> upper bound) mapping is < 1/16.
        for shift in 0..60 {
            for off in [0u64, 1, 7] {
                let v = (1u64 << shift) + off;
                let ub = bucket_upper(bucket_index(v));
                assert!(ub >= v, "upper bound {ub} below value {v}");
                assert!(ub as f64 <= v as f64 * (1.0 + 1.0 / 8.0) + 1.0, "{ub} vs {v}");
            }
        }
    }

    #[test]
    fn quantiles_of_uniform_range() {
        let mut h = LatencyHistogram::new();
        for v in 1..=1000u64 {
            h.record(v * 1000); // 1µs .. 1ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.p50();
        assert!((450_000..=560_000).contains(&p50), "p50 = {p50}");
        let p999 = h.p999();
        assert!(p999 >= 990_000, "p999 = {p999}");
        assert_eq!(h.max(), 1_000_000);
        assert_eq!(h.min(), 1000);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut c = LatencyHistogram::new();
        for i in 0..500u64 {
            a.record(i * 37 + 5);
            c.record(i * 37 + 5);
        }
        for i in 0..300u64 {
            b.record(i * 91 + 11);
            c.record(i * 91 + 11);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.max(), c.max());
        assert_eq!(a.p50(), c.p50());
        assert_eq!(a.p999(), c.p999());
    }

    #[test]
    fn empty_histogram_is_sane() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ms(1_250_000), "1.25");
        assert_eq!(fmt_ns(512), "512ns");
        assert_eq!(fmt_ns(2_500), "2.5µs");
        assert_eq!(fmt_ns(3_000_000), "3.00ms");
    }
}
