//! Log-binned latency histograms (paper §7.1: "we record the observed
//! latency in units of nanoseconds in a histogram of logarithmically-sized
//! bins").
//!
//! HDR-style binning: values are grouped by magnitude (the position of the
//! highest set bit) with 16 linear sub-buckets per magnitude, giving a
//! worst-case quantization error of 1/16 ≈ 6% — ample for reporting p50 /
//! p999 / max as the paper does.

/// Linear sub-buckets per power of two (log2).
const SUB_BITS: u32 = 4;
const SUB_BUCKETS: u64 = 1 << SUB_BITS;

/// A latency histogram over `u64` nanosecond values.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    max: u64,
    min: u64,
    sum: u128,
}

fn bucket_index(value: u64) -> usize {
    let v = value | 1;
    let magnitude = 63 - v.leading_zeros();
    if magnitude < SUB_BITS {
        value as usize
    } else {
        let shift = magnitude - SUB_BITS;
        (((magnitude - SUB_BITS + 1) as u64 * SUB_BUCKETS) as usize) + ((v >> shift) as usize
            - SUB_BUCKETS as usize)
    }
}

/// Upper bound of the bucket with the given index (inverse of
/// `bucket_index`, up to quantization).
fn bucket_upper(index: usize) -> u64 {
    if index < SUB_BUCKETS as usize {
        index as u64
    } else {
        let index = index as u64 - SUB_BUCKETS;
        let magnitude = index / SUB_BUCKETS;
        let sub = index % SUB_BUCKETS;
        ((SUB_BUCKETS + sub + 1) << magnitude) - 1
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; bucket_index(u64::MAX) + 1],
            total: 0,
            max: 0,
            min: u64::MAX,
            sum: 0,
        }
    }

    /// Records one value.
    #[inline]
    pub fn record(&mut self, value: u64) {
        self.counts[bucket_index(value)] += 1;
        self.total += 1;
        self.sum += value as u128;
        if value > self.max {
            self.max = value;
        }
        if value < self.min {
            self.min = value;
        }
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact maximum recorded value.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact minimum recorded value (0 if empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Mean of recorded values.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Value at quantile `q` in `[0, 1]` (bucket upper bound; exact max for
    /// the top).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper(i).min(self.max);
            }
        }
        self.max
    }

    /// Median.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 99.9th percentile (the paper's tail metric).
    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// Merges another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Formats nanoseconds the way the paper's tables do (milliseconds with two
/// decimals).
pub fn fmt_ms(ns: u64) -> String {
    format!("{:.2}", ns as f64 / 1e6)
}

/// Formats nanoseconds adaptively (µs / ms / s) for plots and logs.
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_roundtrip_error_bounded() {
        // Quantization error of the (index -> upper bound) mapping is < 1/16.
        for shift in 0..60 {
            for off in [0u64, 1, 7] {
                let v = (1u64 << shift) + off;
                let ub = bucket_upper(bucket_index(v));
                assert!(ub >= v, "upper bound {ub} below value {v}");
                assert!(ub as f64 <= v as f64 * (1.0 + 1.0 / 8.0) + 1.0, "{ub} vs {v}");
            }
        }
    }

    #[test]
    fn quantiles_of_uniform_range() {
        let mut h = LatencyHistogram::new();
        for v in 1..=1000u64 {
            h.record(v * 1000); // 1µs .. 1ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.p50();
        assert!((450_000..=560_000).contains(&p50), "p50 = {p50}");
        let p999 = h.p999();
        assert!(p999 >= 990_000, "p999 = {p999}");
        assert_eq!(h.max(), 1_000_000);
        assert_eq!(h.min(), 1000);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut c = LatencyHistogram::new();
        for i in 0..500u64 {
            a.record(i * 37 + 5);
            c.record(i * 37 + 5);
        }
        for i in 0..300u64 {
            b.record(i * 91 + 11);
            c.record(i * 91 + 11);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.max(), c.max());
        assert_eq!(a.p50(), c.p50());
        assert_eq!(a.p999(), c.p999());
    }

    #[test]
    fn empty_histogram_is_sane() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ms(1_250_000), "1.25");
        assert_eq!(fmt_ns(512), "512ns");
        assert_eq!(fmt_ns(2_500), "2.5µs");
        assert_eq!(fmt_ns(3_000_000), "3.00ms");
    }

    // -- seeded property tests vs. a sorted-Vec reference ----------------

    /// splitmix64: deterministic, dependency-free pseudo-randomness.
    fn next(state: &mut u64) -> u64 {
        *state = state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = *state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Nearest-rank quantile on a sorted slice — the exact definition
    /// `LatencyHistogram::quantile` approximates.
    fn reference_quantile(sorted: &[u64], q: f64) -> u64 {
        if sorted.is_empty() {
            return 0;
        }
        let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    }

    /// One random value spanning many magnitudes; every fourth draw lands
    /// on or next to an exact bucket boundary (the off-by-one hot spots).
    fn draw(state: &mut u64) -> u64 {
        let r = next(state);
        if r % 4 == 0 {
            let magnitude = SUB_BITS + (next(state) % 46) as u32;
            let sub = next(state) % SUB_BUCKETS;
            let boundary = (SUB_BUCKETS + sub) << (magnitude - SUB_BITS);
            match next(state) % 3 {
                0 => boundary - 1,
                1 => boundary,
                _ => boundary + 1,
            }
        } else {
            next(state) % (1u64 << (4 + next(state) % 50))
        }
    }

    const QS: [f64; 8] = [0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0];

    #[test]
    fn property_quantiles_track_sorted_reference() {
        let mut state = 0x5eed_0b5e_u64 ^ 0xa5a5_a5a5_a5a5_a5a5;
        for case in 0..48usize {
            // Cases 0 and 1 pin the empty and single-value degeneracies.
            let n = match case {
                0 => 0,
                1 => 1,
                _ => (next(&mut state) % 500 + 2) as usize,
            };
            let mut h = LatencyHistogram::new();
            let mut reference = Vec::with_capacity(n);
            for _ in 0..n {
                let v = draw(&mut state);
                h.record(v);
                reference.push(v);
            }
            reference.sort_unstable();
            assert_eq!(h.count(), n as u64, "case {case}");
            assert_eq!(h.min(), reference.first().copied().unwrap_or(0), "case {case}");
            assert_eq!(h.max(), reference.last().copied().unwrap_or(0), "case {case}");
            let exact_mean = if n == 0 {
                0.0
            } else {
                reference.iter().map(|&v| v as u128).sum::<u128>() as f64 / n as f64
            };
            let tolerance = 1e-9 * exact_mean.max(1.0);
            assert!((h.mean() - exact_mean).abs() <= tolerance, "case {case} mean");
            for q in QS {
                let exact = reference_quantile(&reference, q);
                let got = h.quantile(q);
                // The histogram reports the bucket's upper bound (clamped
                // to the exact max): never below the true quantile, and
                // within the 1/16-sub-bucket quantization envelope above.
                assert!(got >= exact, "case {case} q={q}: {got} < exact {exact}");
                assert!(
                    got as f64 <= exact as f64 * (1.0 + 1.0 / 8.0) + 1.0,
                    "case {case} q={q}: {got} too far above exact {exact}"
                );
            }
        }
    }

    #[test]
    fn property_merge_matches_combined_recording() {
        let mut state = 0x00b5_e7_1e5d_u64;
        for case in 0..24usize {
            let parts = (next(&mut state) % 4 + 1) as usize;
            let n = (next(&mut state) % 600) as usize;
            let mut shards = vec![LatencyHistogram::new(); parts];
            let mut combined = LatencyHistogram::new();
            let mut reference = Vec::with_capacity(n);
            for i in 0..n {
                let v = draw(&mut state);
                // Uneven round-robin so some shards stay empty sometimes.
                shards[i % parts].record(v);
                combined.record(v);
                reference.push(v);
            }
            reference.sort_unstable();
            // Fold the shards into one, starting from an empty histogram
            // (merging into empty must not disturb min/max).
            let mut merged = LatencyHistogram::new();
            for shard in &shards {
                merged.merge(shard);
            }
            assert_eq!(merged.count(), combined.count(), "case {case}");
            assert_eq!(merged.min(), combined.min(), "case {case}");
            assert_eq!(merged.max(), combined.max(), "case {case}");
            let tolerance = 1e-9 * combined.mean().max(1.0);
            assert!((merged.mean() - combined.mean()).abs() <= tolerance, "case {case}");
            for q in QS {
                assert_eq!(merged.quantile(q), combined.quantile(q), "case {case} q={q}");
                let exact = reference_quantile(&reference, q);
                assert!(merged.quantile(q) >= exact, "case {case} q={q}");
            }
        }
    }

    #[test]
    fn single_values_report_exactly_at_every_quantile() {
        // A one-value histogram must return that value for every quantile
        // (the upper-bound clamp to the exact max), including values that
        // sit exactly on, just below, and just above bucket boundaries.
        for magnitude in SUB_BITS..60 {
            for sub in [0, 1, SUB_BUCKETS - 1] {
                let boundary = (SUB_BUCKETS + sub) << (magnitude - SUB_BITS);
                for v in [boundary - 1, boundary, boundary + 1] {
                    let mut h = LatencyHistogram::new();
                    h.record(v);
                    assert_eq!(h.count(), 1);
                    assert_eq!(h.min(), v);
                    assert_eq!(h.max(), v);
                    for q in QS {
                        assert_eq!(h.quantile(q), v, "v={v} q={q}");
                    }
                }
            }
        }
    }
}
