//! Streams: handles to an operator output, from which downstream operators
//! are built.

use super::channels::{
    drainer, ChannelSend, ChannelSendHandle, Data, LocalQueue, Message, Pact, TeeHandle,
};
use super::scope::Scope;
use crate::progress::location::Location;
use crate::progress::timestamp::Timestamp;

/// A stream of `(T, D)` message batches flowing out of one operator output
/// port, instantiated on every worker.
pub struct Stream<T: Timestamp, D: Data> {
    /// The output port that produces this stream.
    pub source: Location,
    /// The send sides of channels attached to the port (grows as consumers
    /// connect).
    tee: TeeHandle<T, D>,
    /// The dataflow build state.
    scope: Scope<T>,
}

impl<T: Timestamp, D: Data> Clone for Stream<T, D> {
    fn clone(&self) -> Self {
        Stream { source: self.source, tee: self.tee.clone(), scope: self.scope.clone() }
    }
}

impl<T: Timestamp, D: Data> Stream<T, D> {
    /// Wraps an output port (done by `OperatorBuilder::new_output`).
    pub fn new(source: Location, tee: TeeHandle<T, D>, scope: Scope<T>) -> Self {
        Stream { source, tee, scope }
    }

    /// The dataflow scope this stream belongs to.
    pub fn scope(&self) -> Scope<T> {
        self.scope.clone()
    }

    /// Connects this stream to input port `port` of node `node` with the
    /// given pact, delivering messages into `queue`.
    ///
    /// Allocates the channel (same id on every worker), claims the matching
    /// cross-worker SPSC rings from the fabric, records the graph edge, and
    /// registers the drainers/flushers with the worker.
    pub fn connect_to(&self, node: usize, port: usize, pact: Pact<D>, queue: LocalQueue<T, D>) {
        let mut state = self.scope.state.borrow_mut();
        assert!(!state.finalized, "cannot connect streams after the dataflow started");
        let channel = state.channels;
        state.channels += 1;
        let index = state.index;
        let peers = state.peers;
        let target = Location::target(node, port);
        state.topology.edges.push((self.source, target));

        // Claim remote endpoints: we send on (channel, index, w) and receive
        // on (channel, w, index) for every peer w != index. The fabric
        // routes each pair onto an intra-process ring or a serializing net
        // endpoint by the peer's locality.
        let mut remote = Vec::with_capacity(peers);
        for w in 0..peers {
            if w == index {
                remote.push(None);
            } else {
                remote.push(Some(state.fabric.channel_sender::<Message<T, D>>(channel, index, w)));
                let receiver = state.fabric.channel_receiver::<Message<T, D>>(channel, w, index);
                state.drainers.push(drainer(receiver, queue.clone()));
            }
        }

        let staged_flag = state.remote_staged.clone();
        let stats = state.fabric.stats(index);
        let send: ChannelSendHandle<T, D> = std::rc::Rc::new(std::cell::RefCell::new(
            ChannelSend::new(
                channel,
                target,
                pact,
                index,
                peers,
                remote,
                queue,
                staged_flag,
                stats,
            ),
        ));
        let flush = send.clone();
        state.flushers.push(Box::new(move || flush.borrow_mut().flush_remote()));
        drop(state);
        self.tee.borrow_mut().push(send);
    }
}
