//! Cyclic dataflow: feedback edges with strictly advancing summaries.
//!
//! Timestamp tokens "avoid restrictions on dataflow structure, for example
//! the requirement (seen in Spark and Flink) that dataflow graphs be
//! acyclic" (§5.2). A feedback node forwards records while advancing their
//! timestamps by a declared summary; reachability requires the summary to
//! strictly advance, which keeps frontier computation well-founded.

use super::channels::{Data, Pact};
use super::operator::{InputHandle, OperatorBuilder, OutputHandle};
use super::scope::Scope;
use super::stream::Stream;
use crate::progress::location::Location;
use crate::progress::timestamp::{PartialOrder, PathSummary, Timestamp};

/// The write end of a feedback edge: connect a stream to close the loop.
pub struct LoopHandle<T: Timestamp, D: Data> {
    node: usize,
    queue: super::channels::LocalQueue<T, D>,
    connected: std::cell::Cell<bool>,
}

/// Creates a feedback node whose output stream carries records re-entering
/// the loop with timestamps advanced by `summary`. Returns the handle used
/// to close the loop and the output stream.
///
/// Panics if `summary` does not strictly advance timestamps.
pub fn feedback<T: Timestamp, D: Data>(
    scope: &Scope<T>,
    summary: T::Summary,
) -> (LoopHandle<T, D>, Stream<T, D>) {
    let min = T::minimum();
    let advanced = summary.results_in(&min).expect("summary applies to minimum");
    assert!(
        min.less_than(&advanced),
        "feedback summary must strictly advance timestamps"
    );

    let mut builder = OperatorBuilder::new(scope, "feedback");
    let (queue, frontier, _port) = builder.new_input_deferred::<D>();
    let (tee, stream) = builder.new_output::<D>();
    builder.set_summary(0, 0, summary.clone());
    let (info, activation) = builder.info();
    let node = builder.node();
    let bookkeeping = scope.bookkeeping();
    // Drop the initial token: the feedback node only echoes its input.
    drop(builder.initial_tokens());
    let mut input: InputHandle<T, D> = InputHandle::new(
        queue.clone(),
        frontier,
        Location::target(node, 0),
        Some(Location::source(node, 0)),
        summary,
        bookkeeping.clone(),
    );
    let mut output: OutputHandle<T, D> = OutputHandle::new(
        Location::source(node, 0),
        tee,
        bookkeeping,
        info.worker,
        info.peers,
        scope.send_batch(),
    );
    let tracer = scope.tracer();
    input.set_tracer(tracer.clone());
    output.set_tracer(tracer);
    builder.build(
        activation,
        Box::new(move || {
            while let Some((token, data)) = input.next() {
                // The token ref's capability time is the summary-advanced
                // message time, so the records re-enter one iteration later.
                output.session(&token).give_batch(data);
            }
        }),
    );
    (LoopHandle { node, queue, connected: std::cell::Cell::new(false) }, stream)
}

impl<T: Timestamp, D: Data> LoopHandle<T, D> {
    /// Closes the loop: `stream`'s records flow back through the feedback
    /// node. May only be called once.
    pub fn connect(&self, stream: &Stream<T, D>, pact: Pact<D>) {
        assert!(!self.connected.replace(true), "loop already connected");
        stream.connect_to(self.node, 0, pact, self.queue.clone());
    }
}
