//! Probes: observing a stream's frontier from outside the dataflow.
//!
//! A probe is an output-less operator that consumes (and discards) the
//! stream's records; its input frontier — maintained by the tracker with no
//! operator involvement — tells the driving loop how far the stream has
//! progressed. The open-loop harness uses probes to detect when all results
//! for a timestamp have been produced.

use super::channels::{Data, Pact};
use super::operator::{OperatorExt, OperatorInfo};
use super::stream::Stream;
use crate::progress::antichain::Antichain;
use crate::progress::timestamp::Timestamp;
use crate::progress::tracker::FrontierHandle;

/// A cloneable handle on a probe's observed frontier.
pub struct ProbeHandle<T: Timestamp> {
    frontier: FrontierHandle<T>,
}

impl<T: Timestamp> Clone for ProbeHandle<T> {
    fn clone(&self) -> Self {
        ProbeHandle { frontier: self.frontier.clone() }
    }
}

impl<T: Timestamp> ProbeHandle<T> {
    /// True iff the probed stream may still produce data at `time`.
    pub fn less_equal(&self, time: &T) -> bool {
        self.frontier.borrow().antichain.less_equal(time)
    }

    /// True iff the probed stream may still produce data at some `t < time`.
    pub fn less_than(&self, time: &T) -> bool {
        self.frontier.borrow().antichain.less_than(time)
    }

    /// True iff the probed stream is complete (closed frontier).
    pub fn done(&self) -> bool {
        self.frontier.borrow().antichain.is_empty()
    }

    /// A snapshot of the probed frontier.
    pub fn frontier(&self) -> Antichain<T> {
        self.frontier.borrow().antichain.to_antichain()
    }
}

/// Attaches probes to streams.
pub trait ProbeExt<T: Timestamp, D: Data> {
    /// Consumes the stream (pipeline pact) and exposes its frontier.
    fn probe(&self) -> ProbeHandle<T>;

    /// Probes while passing data through to an inspection closure.
    fn probe_with<F: FnMut(&T, &[D]) + 'static>(&self, logic: F) -> ProbeHandle<T>;
}

impl<T: Timestamp, D: Data> ProbeExt<T, D> for Stream<T, D> {
    fn probe(&self) -> ProbeHandle<T> {
        self.probe_with(|_, _| {})
    }

    fn probe_with<F: FnMut(&T, &[D]) + 'static>(&self, mut logic: F) -> ProbeHandle<T> {
        self.sink(Pact::Pipeline, "probe", move |_info: OperatorInfo| {
            move |input| {
                while let Some((token, data)) = input.next() {
                    logic(token.time(), &data);
                }
            }
        });
        // `sink` hides the frontier handle; the probe's input port is the
        // most recently registered frontier request in the build state.
        let scope = self.scope();
        let state = scope.state.borrow();
        let (_, _, frontier) = state
            .frontier_handles
            .last()
            .expect("probe registered an input port");
        ProbeHandle { frontier: frontier.clone() }
    }
}
