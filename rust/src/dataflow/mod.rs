//! The dataflow layer: graph construction, streams, channels, the timestamp
//! token API (paper §4, Figure 3), and the operator builder (Figure 5).

pub mod channels;
pub mod feedback;
pub mod input;
pub mod operator;
pub mod probe;
pub mod scope;
pub mod stream;
pub mod token;

pub use channels::{Batch, Data, Message, Pact, Route};
pub use feedback::{feedback, LoopHandle};
pub use input::InputSession;
pub use operator::{InputHandle, OperatorBuilder, OperatorExt, OperatorInfo, OutputHandle, Session};
pub use probe::{ProbeExt, ProbeHandle};
pub use scope::{Activator, Scope};
pub use stream::Stream;
pub use token::{BookkeepingHandle, TimestampToken, TimestampTokenRef, TokenTrait};
