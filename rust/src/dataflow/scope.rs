//! The per-worker dataflow build state.
//!
//! Every worker runs the same construction code and produces an identical
//! graph; node and channel identifiers are assigned in construction order,
//! which is how matching communication channels are claimed across workers
//! without coordination.

use super::channels::Data;
use super::token::BookkeepingHandle;
use crate::progress::reachability::GraphTopology;
use crate::progress::timestamp::Timestamp;
use crate::progress::tracker::FrontierHandle;
use crate::worker::allocator::Fabric;
use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::Arc;

/// One registered operator, as the worker's scheduler sees it.
pub struct OpCore<T: Timestamp> {
    /// Operator name (diagnostics).
    pub name: String,
    /// Node index in the dataflow graph.
    pub node: usize,
    /// The operator logic; invoked when the operator is scheduled.
    pub logic: Box<dyn FnMut()>,
    /// True iff the operator has queued input.
    pub work_hint: Box<dyn Fn() -> bool>,
    /// Explicit re-scheduling request (see [`Activator`]).
    pub activation: Rc<Cell<bool>>,
    /// The operator's input-port frontier handles (scheduling triggers).
    pub frontiers: Vec<FrontierHandle<T>>,
}

/// A handle operators can use to request re-invocation even without new
/// input or frontier movement — the mechanism behind co-operative flow
/// control (§6.1: an operator "yields control without yielding the right to
/// resume execution").
#[derive(Clone)]
pub struct Activator {
    flag: Rc<Cell<bool>>,
}

impl Activator {
    pub(crate) fn new(flag: Rc<Cell<bool>>) -> Self {
        Activator { flag }
    }

    /// Requests that the operator be scheduled again.
    pub fn activate(&self) {
        self.flag.set(true);
    }
}

/// The mutable state accumulated while a worker builds its dataflow.
pub struct BuildState<T: Timestamp> {
    /// This worker's index.
    pub index: usize,
    /// Total number of workers.
    pub peers: usize,
    /// The cross-worker communication fabric.
    pub fabric: Arc<Fabric>,
    /// The worker-wide shared bookkeeping that all tokens write to.
    pub bookkeeping: BookkeepingHandle<T>,
    /// The graph topology under construction.
    pub topology: GraphTopology<T>,
    /// Registered operators (moved into the worker at finalization).
    pub ops: Vec<OpCore<T>>,
    /// Frontier handles created during construction, adopted by the tracker.
    pub frontier_handles: Vec<(usize, usize, FrontierHandle<T>)>,
    /// Drainers that move remote messages into local mailboxes.
    pub drainers: Vec<Box<dyn FnMut() -> bool>>,
    /// Flushers that release staged remote messages after the worker's
    /// progress broadcast; each returns `(sent_any, remaining)` so the
    /// worker can keep its remote-pending latch set behind full rings.
    pub flushers: Vec<Box<dyn FnMut() -> (bool, bool)>>,
    /// Records buffered per output session before a batch is posted
    /// (settable through `Config::send_batch` before construction).
    pub send_batch: usize,
    /// Channel id counter.
    pub channels: usize,
    /// Set once the worker has built its tracker; no more graph mutation.
    pub finalized: bool,
    /// Raised by any channel that stages remote data this step (forces the
    /// worker to append its progress batch before releasing the fabric).
    pub remote_staged: Rc<Cell<bool>>,
    /// The worker's checkpoint/restore context, when checkpointing or
    /// recovery is configured (u64-timestamped dataflows only). Stateful
    /// operators register their cells here at construction time.
    pub recovery: Option<Rc<crate::recovery::RecoveryContext>>,
    /// The worker's event tracer, when observability is configured.
    /// Operator handles clone it at construction time to stamp
    /// records-in/out; `None` (the default) costs one branch per hook.
    pub tracer: Option<Rc<crate::observe::WorkerTracer>>,
}

impl<T: Timestamp> BuildState<T> {
    /// Fresh build state for one worker.
    pub fn new(index: usize, peers: usize, fabric: Arc<Fabric>) -> Self {
        BuildState {
            index,
            peers,
            fabric,
            bookkeeping: BookkeepingHandle::new(),
            topology: GraphTopology::default(),
            ops: Vec::new(),
            frontier_handles: Vec::new(),
            drainers: Vec::new(),
            flushers: Vec::new(),
            send_batch: crate::config::SEND_BATCH,
            channels: 0,
            finalized: false,
            remote_staged: Rc::new(Cell::new(false)),
            recovery: None,
            tracer: None,
        }
    }

    /// Allocates the next channel id.
    pub fn next_channel(&mut self) -> usize {
        assert!(!self.finalized, "cannot add channels after the dataflow started");
        let id = self.channels;
        self.channels += 1;
        id
    }
}

/// A cloneable handle on the build state; held by [`super::stream::Stream`]s
/// and operator builders.
pub struct Scope<T: Timestamp> {
    pub(crate) state: Rc<RefCell<BuildState<T>>>,
}

impl<T: Timestamp> Clone for Scope<T> {
    fn clone(&self) -> Self {
        Scope { state: self.state.clone() }
    }
}

impl<T: Timestamp> Scope<T> {
    /// Wraps freshly created build state.
    pub fn new(state: BuildState<T>) -> Self {
        Scope { state: Rc::new(RefCell::new(state)) }
    }

    /// This worker's index.
    pub fn index(&self) -> usize {
        self.state.borrow().index
    }

    /// Total number of workers.
    pub fn peers(&self) -> usize {
        self.state.borrow().peers
    }

    /// The worker-wide bookkeeping handle.
    pub fn bookkeeping(&self) -> BookkeepingHandle<T> {
        self.state.borrow().bookkeeping.clone()
    }

    /// Records per output batch (the configured `SEND_BATCH`).
    pub fn send_batch(&self) -> usize {
        self.state.borrow().send_batch
    }

    /// The worker's checkpoint/restore context, if one is installed.
    /// Stateful operators call this at construction time to register their
    /// [`crate::recovery::EpochSealed`] cells (and restore them when
    /// recovering); `None` means checkpointing is off and cells should
    /// skip update logging.
    pub fn recovery(&self) -> Option<Rc<crate::recovery::RecoveryContext>> {
        self.state.borrow().recovery.clone()
    }

    /// The worker's event tracer, if observability is on. Handles and
    /// input sessions clone this at construction time so the hot path
    /// never goes back through the scope.
    pub fn tracer(&self) -> Option<Rc<crate::observe::WorkerTracer>> {
        self.state.borrow().tracer.clone()
    }
}

/// Marker alias so signatures read naturally.
pub trait ScopeData: Data {}
impl<D: Data> ScopeData for D {}
