//! Feeding data into a dataflow from outside operator logic.
//!
//! An [`InputSession`] holds a [`TimestampToken`] for the input node's
//! output port and uses it to send timestamped records; `advance_to`
//! downgrades the token (releasing earlier timestamps system-wide) and
//! `close` drops it. This is the paper's §4.2 case of tokens "used outside
//! the operators their pointstamps reference ... especially useful for
//! manual control of inputs to a dataflow": the worker drains the shared
//! bookkeeping at the start of every step, picking up input actions taken
//! between steps.

use super::channels::Data;
use super::operator::{OperatorBuilder, OutputHandle};
use super::scope::Scope;
use super::stream::Stream;
use super::token::TimestampToken;
use crate::progress::location::Location;
use crate::progress::timestamp::{PartialOrder, Timestamp};
use crate::runtime::RuntimeError;

/// A handle for introducing timestamped records into a dataflow.
pub struct InputSession<T: Timestamp, D: Data> {
    /// The input's timestamp token; `None` once closed.
    token: Option<TimestampToken<T>>,
    output: OutputHandle<T, D>,
    /// Records buffered at the current epoch (capacity reused across
    /// flushes — the steady-state feed path does not allocate).
    buffer: Vec<D>,
    /// Records per flush (the configured `SEND_BATCH`).
    send_batch: usize,
    time: T,
    /// Event tracer: `advance_to` marks start each epoch's latency clock.
    tracer: Option<std::rc::Rc<crate::observe::WorkerTracer>>,
}

impl<T: Timestamp, D: Data> InputSession<T, D> {
    /// Builds the input node and returns the session and its stream.
    /// (Reached through `Worker::new_input`.)
    pub(crate) fn new(scope: &Scope<T>) -> (Self, Stream<T, D>) {
        let mut builder = OperatorBuilder::new(scope, "input");
        let (tee, stream) = builder.new_output::<D>();
        let (info, activation) = builder.info();
        let node = builder.node();
        let mut tokens = builder.initial_tokens();
        let token = tokens.pop().expect("input has one output");
        let send_batch = scope.send_batch();
        let output = OutputHandle::new(
            Location::source(node, 0),
            tee,
            scope.bookkeeping(),
            info.worker,
            info.peers,
            send_batch,
        );
        // The input node has no operator logic: its messages originate here.
        builder.build(activation, Box::new(|| {}));
        let mut token = token;
        let mut time = T::minimum();
        // Recovering: rewind to the first un-checkpointed epoch. The
        // restored state already reflects everything at `<= resume`, so
        // the session (and its token) starts at `resume + 1`; the driver
        // replays its input from there (`Worker::resume_epoch`). Only u64
        // dataflows carry a recovery context.
        if let Some(ctx) = scope.recovery() {
            if ctx.is_restoring() {
                if let Some(t) =
                    (&mut time as &mut dyn std::any::Any).downcast_mut::<u64>()
                {
                    *t = ctx.resume_epoch() + 1;
                    token.downgrade(&time);
                }
            }
        }
        (
            InputSession {
                token: Some(token),
                output,
                buffer: Vec::new(),
                send_batch,
                time,
                tracer: scope.tracer(),
            },
            stream,
        )
    }

    /// The current epoch.
    pub fn time(&self) -> &T {
        &self.time
    }

    /// Buffers one record at the current epoch.
    ///
    /// Panics on a closed input; the serve command plane (and any other
    /// path where "closed" is a runtime condition rather than a
    /// programming error) uses [`try_send`](Self::try_send).
    pub fn send(&mut self, record: D) {
        self.try_send(record).expect("send on closed input");
    }

    /// Fallible [`send`](Self::send): a closed input is reported as a
    /// typed [`RuntimeError`] instead of a panic.
    pub fn try_send(&mut self, record: D) -> Result<(), RuntimeError> {
        if self.token.is_none() {
            return Err(RuntimeError::msg("send on closed input"));
        }
        self.buffer.push(record);
        if self.buffer.len() >= self.send_batch {
            self.try_flush()?;
        }
        Ok(())
    }

    /// Buffers many records at the current epoch. Panics on a closed
    /// input; see [`try_send_batch`](Self::try_send_batch).
    pub fn send_batch(&mut self, records: &mut Vec<D>) {
        self.try_send_batch(records).expect("send on closed input");
    }

    /// Fallible [`send_batch`](Self::send_batch); on a closed input the
    /// records are left untouched and a typed error is returned.
    pub fn try_send_batch(&mut self, records: &mut Vec<D>) -> Result<(), RuntimeError> {
        if self.token.is_none() {
            return Err(RuntimeError::msg("send on closed input"));
        }
        if self.buffer.is_empty() {
            std::mem::swap(&mut self.buffer, records);
        } else {
            self.buffer.append(records);
        }
        if self.buffer.len() >= self.send_batch {
            self.try_flush()?;
        }
        Ok(())
    }

    /// Flushes buffered records as a message batch at the current epoch.
    /// Panics on a closed input; see [`try_flush`](Self::try_flush).
    pub fn flush(&mut self) {
        self.try_flush().expect("flush on closed input");
    }

    /// Fallible [`flush`](Self::flush).
    pub fn try_flush(&mut self) -> Result<(), RuntimeError> {
        if !self.buffer.is_empty() {
            let token = match self.token.as_ref() {
                Some(token) => token,
                None => return Err(RuntimeError::msg("flush on closed input")),
            };
            let mut session = self.output.session(token);
            // Drain in place: the buffer keeps its capacity for the next
            // epoch instead of handing it to the allocator every flush.
            session.give_iterator(self.buffer.drain(..));
        }
        Ok(())
    }

    /// Advances the epoch to `time`, flushing buffered records and
    /// downgrading the input's token so the system can advance frontiers.
    /// Panics on a closed input or a non-monotone epoch; see
    /// [`try_advance_to`](Self::try_advance_to).
    pub fn advance_to(&mut self, time: T) {
        self.try_advance_to(time).expect("advance_to failed");
    }

    /// Fallible [`advance_to`](Self::advance_to): a closed input or an
    /// epoch regression is reported as a typed [`RuntimeError`].
    pub fn try_advance_to(&mut self, time: T) -> Result<(), RuntimeError> {
        if self.token.is_none() {
            return Err(RuntimeError::msg("advance_to on closed input"));
        }
        if !self.time.less_equal(&time) {
            return Err(RuntimeError::msg(format!(
                "input epochs must advance: {:?} -> {:?}",
                self.time, time
            )));
        }
        self.try_flush()?;
        self.token.as_mut().unwrap().downgrade(&time);
        self.time = time;
        if let Some(tracer) = &self.tracer {
            // The epoch's latency clock starts at its first advance
            // (u64-timestamped dataflows; attribution needs a word).
            if let Some(t) = (&self.time as &dyn std::any::Any).downcast_ref::<u64>() {
                tracer.emit_at(
                    crate::observe::EventKind::InputAdvance,
                    tracer.now_ns(),
                    0,
                    *t,
                    0,
                    0,
                );
            }
        }
        Ok(())
    }

    /// Closes the input: flushes and drops the token. Idempotent.
    pub fn close(&mut self) {
        if self.token.is_some() {
            self.flush();
            self.token = None;
        }
    }

    /// True iff the input has been closed.
    pub fn is_closed(&self) -> bool {
        self.token.is_none()
    }
}

impl<T: Timestamp, D: Data> Drop for InputSession<T, D> {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use crate::dataflow::probe::ProbeExt;
    use crate::worker::execute::execute_single;

    #[test]
    fn closed_input_reports_typed_errors() {
        execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = worker.new_input::<u64>();
            let probe = stream.probe();
            input.advance_to(1);
            input.send(7);
            input.close();
            assert!(input.is_closed());
            // Every fallible entry point reports a typed error rather
            // than panicking...
            let err = input.try_send(8).unwrap_err();
            assert!(format!("{err}").contains("closed input"), "{err}");
            let mut batch = vec![1, 2, 3];
            assert!(input.try_send_batch(&mut batch).is_err());
            assert_eq!(batch, vec![1, 2, 3], "records must be left untouched on error");
            assert!(input.try_advance_to(2).is_err());
            // ...and closing again stays idempotent.
            input.close();
            worker.step_while(|| !probe.done());
        });
    }

    #[test]
    fn epoch_regression_is_a_typed_error() {
        execute_single::<u64, _, _>(|worker| {
            let (mut input, _stream) = worker.new_input::<u64>();
            input.advance_to(5);
            let err = input.try_advance_to(3).unwrap_err();
            assert!(format!("{err}").contains("must advance"), "{err}");
            input.close();
            while !worker.is_complete() {
                worker.step();
            }
        });
    }

    #[test]
    fn panicking_wrappers_still_panic_with_the_typed_message() {
        execute_single::<u64, _, _>(|worker| {
            let (mut input, _stream) = worker.new_input::<u64>();
            input.close();
            // The infallible API keeps its contract: programming errors
            // panic, and the message carries the typed error's text.
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                input.send(8);
            }));
            let payload = caught.unwrap_err();
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default();
            assert!(msg.contains("send on closed input"), "{msg}");
            while !worker.is_complete() {
                worker.step();
            }
        });
    }
}
