//! Feeding data into a dataflow from outside operator logic.
//!
//! An [`InputSession`] holds a [`TimestampToken`] for the input node's
//! output port and uses it to send timestamped records; `advance_to`
//! downgrades the token (releasing earlier timestamps system-wide) and
//! `close` drops it. This is the paper's §4.2 case of tokens "used outside
//! the operators their pointstamps reference ... especially useful for
//! manual control of inputs to a dataflow": the worker drains the shared
//! bookkeeping at the start of every step, picking up input actions taken
//! between steps.

use super::channels::Data;
use super::operator::{OperatorBuilder, OutputHandle};
use super::scope::Scope;
use super::stream::Stream;
use super::token::TimestampToken;
use crate::progress::location::Location;
use crate::progress::timestamp::{PartialOrder, Timestamp};

/// A handle for introducing timestamped records into a dataflow.
pub struct InputSession<T: Timestamp, D: Data> {
    /// The input's timestamp token; `None` once closed.
    token: Option<TimestampToken<T>>,
    output: OutputHandle<T, D>,
    /// Records buffered at the current epoch (capacity reused across
    /// flushes — the steady-state feed path does not allocate).
    buffer: Vec<D>,
    /// Records per flush (the configured `SEND_BATCH`).
    send_batch: usize,
    time: T,
    /// Event tracer: `advance_to` marks start each epoch's latency clock.
    tracer: Option<std::rc::Rc<crate::observe::WorkerTracer>>,
}

impl<T: Timestamp, D: Data> InputSession<T, D> {
    /// Builds the input node and returns the session and its stream.
    /// (Reached through `Worker::new_input`.)
    pub(crate) fn new(scope: &Scope<T>) -> (Self, Stream<T, D>) {
        let mut builder = OperatorBuilder::new(scope, "input");
        let (tee, stream) = builder.new_output::<D>();
        let (info, activation) = builder.info();
        let node = builder.node();
        let mut tokens = builder.initial_tokens();
        let token = tokens.pop().expect("input has one output");
        let send_batch = scope.send_batch();
        let output = OutputHandle::new(
            Location::source(node, 0),
            tee,
            scope.bookkeeping(),
            info.worker,
            info.peers,
            send_batch,
        );
        // The input node has no operator logic: its messages originate here.
        builder.build(activation, Box::new(|| {}));
        let mut token = token;
        let mut time = T::minimum();
        // Recovering: rewind to the first un-checkpointed epoch. The
        // restored state already reflects everything at `<= resume`, so
        // the session (and its token) starts at `resume + 1`; the driver
        // replays its input from there (`Worker::resume_epoch`). Only u64
        // dataflows carry a recovery context.
        if let Some(ctx) = scope.recovery() {
            if ctx.is_restoring() {
                if let Some(t) =
                    (&mut time as &mut dyn std::any::Any).downcast_mut::<u64>()
                {
                    *t = ctx.resume_epoch() + 1;
                    token.downgrade(&time);
                }
            }
        }
        (
            InputSession {
                token: Some(token),
                output,
                buffer: Vec::new(),
                send_batch,
                time,
                tracer: scope.tracer(),
            },
            stream,
        )
    }

    /// The current epoch.
    pub fn time(&self) -> &T {
        &self.time
    }

    /// Buffers one record at the current epoch.
    pub fn send(&mut self, record: D) {
        assert!(self.token.is_some(), "send on closed input");
        self.buffer.push(record);
        if self.buffer.len() >= self.send_batch {
            self.flush();
        }
    }

    /// Buffers many records at the current epoch.
    pub fn send_batch(&mut self, records: &mut Vec<D>) {
        assert!(self.token.is_some(), "send on closed input");
        if self.buffer.is_empty() {
            std::mem::swap(&mut self.buffer, records);
        } else {
            self.buffer.append(records);
        }
        if self.buffer.len() >= self.send_batch {
            self.flush();
        }
    }

    /// Flushes buffered records as a message batch at the current epoch.
    pub fn flush(&mut self) {
        if !self.buffer.is_empty() {
            let token = self.token.as_ref().expect("flush on closed input");
            let mut session = self.output.session(token);
            // Drain in place: the buffer keeps its capacity for the next
            // epoch instead of handing it to the allocator every flush.
            session.give_iterator(self.buffer.drain(..));
        }
    }

    /// Advances the epoch to `time`, flushing buffered records and
    /// downgrading the input's token so the system can advance frontiers.
    pub fn advance_to(&mut self, time: T) {
        assert!(
            self.token.is_some(),
            "advance_to on closed input"
        );
        assert!(
            self.time.less_equal(&time),
            "input epochs must advance: {:?} -> {:?}",
            self.time,
            time
        );
        self.flush();
        self.token.as_mut().unwrap().downgrade(&time);
        self.time = time;
        if let Some(tracer) = &self.tracer {
            // The epoch's latency clock starts at its first advance
            // (u64-timestamped dataflows; attribution needs a word).
            if let Some(t) = (&self.time as &dyn std::any::Any).downcast_ref::<u64>() {
                tracer.emit_at(
                    crate::observe::EventKind::InputAdvance,
                    tracer.now_ns(),
                    0,
                    *t,
                    0,
                    0,
                );
            }
        }
    }

    /// Closes the input: flushes and drops the token. Idempotent.
    pub fn close(&mut self) {
        if self.token.is_some() {
            self.flush();
            self.token = None;
        }
    }

    /// True iff the input has been closed.
    pub fn is_closed(&self) -> bool {
        self.token.is_none()
    }
}

impl<T: Timestamp, D: Data> Drop for InputSession<T, D> {
    fn drop(&mut self) {
        self.close();
    }
}
