//! Operator construction: the API of the paper's Figure 5.
//!
//! [`OperatorBuilder`] is the low-level interface; [`OperatorExt`] provides
//! `unary`, `unary_frontier`, and `binary_frontier`, whose constructors
//! receive the operator's initial [`TimestampToken`] (§3.1: "each dataflow
//! operator is initially provided with a timestamp token for each of its
//! output edges") and return the repeatedly invoked operator logic.
//!
//! Output batching is allocation-free in the steady state: each
//! [`OutputHandle`] checks its per-destination buffers out of a recycling
//! [`BufferPool`] (consumers return them on drop, even across worker
//! threads), shares broadcast batches through one `Arc` per batch (a
//! [`SharedPool`] recycles buffer *and* control block once every peer has
//! dropped its clone), and moves — rather than clones — each record into
//! the last channel attached to the port.
//!
//! It is also *copy-free* on the forwarding path: when a session is handed
//! a uniquely owned [`Batch::Owned`] lease and the output feeds exactly one
//! [`Pact::Pipeline`] channel, [`Session::give_batch`] forwards the lease
//! **whole** — no per-record move, no re-buffering; the same heap buffer
//! travels the entire pipeline and returns to the pool that minted it when
//! the final consumer drops it (see [`OutputHandle::try_forward`]).

use super::channels::{Batch, Data, LocalQueue, Message, Pact, Route, TeeHandle};
use super::scope::{Activator, OpCore, Scope};
use super::stream::Stream;
use super::token::{BookkeepingHandle, TimestampToken, TimestampTokenRef, TokenTrait};
use crate::buffer::{BufferPool, Lease, SharedPool};
use crate::progress::antichain::MutableAntichain;
use crate::progress::location::Location;
use crate::progress::reachability::NodeTopology;
use crate::progress::timestamp::{PathSummary, Timestamp};
use crate::progress::tracker::{FrontierHandle, SharedFrontier};
use std::cell::{Cell, Ref, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::Arc;

/// Idle buffers retained per output pool. Bounds pool memory at
/// `POOL_SLOTS × send_batch × size_of::<D>()` per output port while easily
/// covering the buffers simultaneously in flight across peers.
const POOL_SLOTS: usize = 32;

/// In-flight broadcast batches tracked for reclamation per output.
const SHARED_POOL_WINDOW: usize = 16;

/// Static facts about an operator instance, handed to its constructor.
#[derive(Clone)]
pub struct OperatorInfo {
    /// The node index in the dataflow graph.
    pub node: usize,
    /// This worker's index.
    pub worker: usize,
    /// Total number of workers.
    pub peers: usize,
    /// Re-scheduling handle (co-operative flow control, §6.1).
    pub activator: Activator,
}

/// The read side of one operator input port.
///
/// Yields `(TimestampTokenRef, batch)` pairs — each message batch arrives
/// "bearing a timestamp token that can be used by the recipient" (§4.1) —
/// and exposes the port's frontier as maintained by the tracker.
pub struct InputHandle<T: Timestamp, D: Data> {
    queue: LocalQueue<T, D>,
    frontier: FrontierHandle<T>,
    target: Location,
    /// Where a retained token would live (`None` for output-less operators).
    retain_location: Option<Location>,
    /// The internal summary from this input to output 0 (identity for
    /// ordinary operators; strictly advancing for feedback).
    retain_summary: T::Summary,
    bookkeeping: BookkeepingHandle<T>,
    /// Event tracer for records-in accounting (`None` = tracing off; the
    /// hook costs one branch).
    tracer: Option<Rc<crate::observe::WorkerTracer>>,
}

impl<T: Timestamp, D: Data> InputHandle<T, D> {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        queue: LocalQueue<T, D>,
        frontier: FrontierHandle<T>,
        target: Location,
        retain_location: Option<Location>,
        retain_summary: T::Summary,
        bookkeeping: BookkeepingHandle<T>,
    ) -> Self {
        InputHandle {
            queue,
            frontier,
            target,
            retain_location,
            retain_summary,
            bookkeeping,
            tracer: None,
        }
    }

    /// Installs the worker's event tracer (construction time only).
    pub(crate) fn set_tracer(&mut self, tracer: Option<Rc<crate::observe::WorkerTracer>>) {
        self.tracer = tracer;
    }

    /// Pops the next message batch, recording its consumption with the
    /// system. The returned token reference cannot outlive the read — call
    /// [`TimestampTokenRef::retain`] to keep a token.
    ///
    /// The batch iterates by value (moving records out of point-to-point
    /// batches, cloning them out of shared broadcast ones); dropping it
    /// returns the pooled buffer to the producing output's pool.
    pub fn next(&mut self) -> Option<(TimestampTokenRef<'_, T>, Batch<D>)> {
        let message = self.queue.borrow_mut().pop_front()?;
        let Message { time, data, .. } = message;
        if let Some(tracer) = &self.tracer {
            tracer.note_records_in(data.len() as u64);
        }
        self.bookkeeping.update(self.target, time.clone(), -1);
        let cap_time = self
            .retain_summary
            .results_in(&time)
            .expect("internal summary overflowed the timestamp domain");
        Some((
            TimestampTokenRef::new(time, cap_time, self.retain_location, &self.bookkeeping),
            data,
        ))
    }

    /// Applies `logic` to every queued batch.
    pub fn for_each<L: FnMut(TimestampTokenRef<'_, T>, Batch<D>)>(&mut self, mut logic: L) {
        while let Some((token, data)) = self.next() {
            logic(token, data);
        }
    }

    /// The port's current frontier — the lower bound on timestamps that may
    /// still appear on this input (§3.2).
    pub fn frontier(&self) -> Ref<'_, MutableAntichain<T>> {
        Ref::map(self.frontier.borrow(), |shared| &shared.antichain)
    }

    /// True iff the frontier has passed `t` (no more data at `t` or earlier
    /// can arrive).
    pub fn frontier_beyond(&self, t: &T) -> bool {
        !self.frontier.borrow().antichain.less_equal(t)
    }

    /// True iff the input is complete (closed frontier, empty queue).
    pub fn is_done(&self) -> bool {
        self.frontier.borrow().antichain.is_empty() && self.queue.borrow().is_empty()
    }
}

/// Per-channel output buffering state (one entry per attached channel).
struct ChannelBuffers<D> {
    /// Per-destination batches under construction (`None` until the first
    /// record; the lease comes from the output's pool).
    per_dest: Vec<Option<Lease<Vec<D>>>>,
    /// Broadcast batch under construction (uniquely referenced until
    /// posted; shared across peers at post time).
    all: Option<Arc<Vec<D>>>,
}

/// Where one record of one channel should be buffered.
enum Disposition {
    ToWorker(usize),
    Broadcast,
}

/// The write side of one operator output port (Ⓗ in the paper's Figure 3).
pub struct OutputHandle<T: Timestamp, D: Data> {
    source: Location,
    tee: TeeHandle<T, D>,
    bookkeeping: BookkeepingHandle<T>,
    peers: usize,
    worker: usize,
    /// Records per batch before it is posted.
    batch_size: usize,
    /// Recycling pool behind the per-destination buffers; consumers return
    /// buffers here when they drop drained batches.
    pool: BufferPool<Vec<D>>,
    /// Recycler for shared broadcast batches.
    shared_pool: SharedPool<Vec<D>>,
    /// Per-channel buffers, aligned with `pacts`.
    buffers: Vec<ChannelBuffers<D>>,
    /// Pact snapshot aligned with `tee` (channels only ever append).
    pacts: Vec<Pact<D>>,
    /// Event tracer for records-out accounting (`None` = tracing off).
    tracer: Option<Rc<crate::observe::WorkerTracer>>,
}

impl<T: Timestamp, D: Data> OutputHandle<T, D> {
    pub(crate) fn new(
        source: Location,
        tee: TeeHandle<T, D>,
        bookkeeping: BookkeepingHandle<T>,
        worker: usize,
        peers: usize,
        batch_size: usize,
    ) -> Self {
        OutputHandle {
            source,
            tee,
            bookkeeping,
            peers,
            worker,
            batch_size: batch_size.max(1),
            pool: BufferPool::new(POOL_SLOTS),
            shared_pool: SharedPool::new(SHARED_POOL_WINDOW),
            buffers: Vec::new(),
            pacts: Vec::new(),
            tracer: None,
        }
    }

    /// Installs the worker's event tracer (construction time only).
    pub(crate) fn set_tracer(&mut self, tracer: Option<Rc<crate::observe::WorkerTracer>>) {
        self.tracer = tracer;
    }

    /// Obtains a session that can send data at the timestamp associated with
    /// timestamp token `tok` (Ⓘ). Accepts owned tokens and token references
    /// alike ([`TokenTrait`]); the token's location is checked against this
    /// output.
    ///
    /// The borrow of `tok` ensures at compile time that the token cannot be
    /// modified or dropped while the session is active.
    pub fn session<'a>(&'a mut self, tok: &'a impl TokenTrait<T>) -> Session<'a, T, D> {
        if let Some(location) = tok.session_location() {
            assert_eq!(
                location, self.source,
                "timestamp token is not valid for this output"
            );
        }
        let time = tok.session_time().clone();
        Session { output: self, time }
    }

    /// Refreshes the pact snapshot (channels may attach after construction).
    fn ensure_buffers(&mut self) {
        let tee = self.tee.borrow();
        while self.pacts.len() < tee.len() {
            self.pacts.push(tee[self.pacts.len()].borrow().pact.clone());
            self.buffers.push(ChannelBuffers {
                per_dest: (0..self.peers).map(|_| None).collect(),
                all: None,
            });
        }
    }

    /// Routes one record into the buffers of every attached channel,
    /// cloning for all but the last channel and *moving* it into the last —
    /// the single-consumer case (by far the common one) never clones.
    fn give(&mut self, time: &T, record: D) {
        self.ensure_buffers();
        let channels = self.pacts.len();
        if channels == 0 {
            return; // no consumers attached: the record has nowhere to go
        }
        for ci in 0..channels - 1 {
            self.give_to(ci, time, record.clone());
        }
        self.give_to(channels - 1, time, record);
    }

    /// Buffers one record on channel `ci`, posting batches as they fill.
    fn give_to(&mut self, ci: usize, time: &T, record: D) {
        let disposition = match &self.pacts[ci] {
            Pact::Pipeline => Disposition::ToWorker(self.worker),
            Pact::Exchange(route) => match route(&record) {
                Route::Worker(hash) => {
                    Disposition::ToWorker((hash % self.peers as u64) as usize)
                }
                Route::All => Disposition::Broadcast,
            },
        };
        match disposition {
            Disposition::ToWorker(dest) => {
                // Order barrier: a pending broadcast batch was given first
                // and must be delivered first.
                if self.buffers[ci].all.is_some() {
                    self.post_broadcast(ci, time);
                }
                let pool = &self.pool;
                let lease = self.buffers[ci].per_dest[dest]
                    .get_or_insert_with(|| pool.checkout());
                lease.push(record);
                if lease.len() >= self.batch_size {
                    self.post(ci, dest, time);
                }
            }
            Disposition::Broadcast => {
                // Order barrier: flush per-destination batches given first.
                for dest in 0..self.peers {
                    if self.buffers[ci].per_dest[dest].is_some() {
                        self.post(ci, dest, time);
                    }
                }
                let shared_pool = &mut self.shared_pool;
                let arc = self.buffers[ci].all.get_or_insert_with(|| shared_pool.checkout());
                let buffer = Arc::get_mut(arc).expect("buffered broadcast batch is unique");
                buffer.push(record);
                if buffer.len() >= self.batch_size {
                    self.post_broadcast(ci, time);
                }
            }
        }
    }

    /// Finalizes a point-to-point batch: records `+1` at the channel target
    /// and enqueues the message (local mailboxes immediately; remote staged
    /// until the worker's progress broadcast).
    fn post(&mut self, ci: usize, dest: usize, time: &T) {
        let Some(lease) = self.buffers[ci].per_dest[dest].take() else { return };
        if lease.is_empty() {
            self.buffers[ci].per_dest[dest] = Some(lease);
            return;
        }
        if let Some(tracer) = &self.tracer {
            tracer.note_records_out(lease.len() as u64);
        }
        let tee = self.tee.borrow();
        let mut channel = tee[ci].borrow_mut();
        self.bookkeeping.update(channel.target, time.clone(), 1);
        channel.push(
            dest,
            Message { time: time.clone(), data: Batch::Owned(lease), from: self.worker },
        );
    }

    /// Finalizes a broadcast batch: one shared `Arc` clone per peer (no
    /// record copies), one `+1` produce count per delivery.
    fn post_broadcast(&mut self, ci: usize, time: &T) {
        let Some(arc) = self.buffers[ci].all.take() else { return };
        if arc.is_empty() {
            self.buffers[ci].all = Some(arc);
            return;
        }
        if let Some(tracer) = &self.tracer {
            // Records *produced* once, however many peers receive them.
            tracer.note_records_out(arc.len() as u64);
        }
        // Track for reclamation once every peer drops its clone.
        self.shared_pool.track(&arc);
        let tee = self.tee.borrow();
        let mut channel = tee[ci].borrow_mut();
        for dest in 0..self.peers {
            self.bookkeeping.update(channel.target, time.clone(), 1);
            channel.push(
                dest,
                Message { time: time.clone(), data: Batch::Shared(arc.clone()), from: self.worker },
            );
        }
    }

    /// Attempts to forward a uniquely owned batch *whole* at `time`: no
    /// per-record move, no re-buffering — the lease itself becomes the
    /// message payload, and its buffer returns to whichever pool minted it
    /// when the (final) consumer drops it.
    ///
    /// Succeeds only when this output feeds exactly one channel and that
    /// channel is [`Pact::Pipeline`] (the destination is this worker, no
    /// routing decisions per record); otherwise the lease is handed back
    /// for the per-record path. Records buffered earlier in the session
    /// are posted first, so delivery order is preserved.
    fn try_forward(&mut self, time: &T, lease: Lease<Vec<D>>) -> Result<(), Lease<Vec<D>>> {
        self.ensure_buffers();
        if self.pacts.len() != 1 || !matches!(self.pacts[0], Pact::Pipeline) {
            return Err(lease);
        }
        if lease.is_empty() {
            // Nothing to deliver; dropping the lease recycles its buffer.
            return Ok(());
        }
        let dest = self.worker;
        // Order barrier: records given earlier in this session must be
        // delivered before the forwarded batch. (A pipeline channel never
        // holds a broadcast buffer, so `per_dest` is the only case.)
        if self.buffers[0].per_dest[dest].is_some() {
            self.post(0, dest, time);
        }
        if let Some(tracer) = &self.tracer {
            tracer.note_records_out(lease.len() as u64);
        }
        let tee = self.tee.borrow();
        let mut channel = tee[0].borrow_mut();
        self.bookkeeping.update(channel.target, time.clone(), 1);
        channel.push(
            dest,
            Message { time: time.clone(), data: Batch::Owned(lease), from: self.worker },
        );
        Ok(())
    }

    /// Flushes all buffered records at `time`.
    ///
    /// Per channel, at most one kind of buffer is pending (the give-order
    /// barriers in `give_to` post the other kind eagerly), so flush order
    /// here cannot reorder deliveries.
    fn flush(&mut self, time: &T) {
        self.ensure_buffers();
        for ci in 0..self.pacts.len() {
            self.post_broadcast(ci, time);
            for dest in 0..self.peers {
                if self.buffers[ci].per_dest[dest].is_some() {
                    self.post(ci, dest, time);
                }
            }
        }
    }
}

/// An active output session at a fixed timestamp; created from a timestamp
/// token by [`OutputHandle::session`]. Buffers records and flushes them as
/// message batches when dropped.
pub struct Session<'a, T: Timestamp, D: Data> {
    output: &'a mut OutputHandle<T, D>,
    time: T,
}

impl<'a, T: Timestamp, D: Data> Session<'a, T, D> {
    /// Sends one record at the session timestamp.
    #[inline]
    pub fn give(&mut self, record: D) {
        self.output.give(&self.time, record);
    }

    /// Sends every record of an iterator.
    pub fn give_iterator<I: Iterator<Item = D>>(&mut self, iter: I) {
        for record in iter {
            self.give(record);
        }
    }

    /// Sends a vector of records.
    pub fn give_vec(&mut self, mut records: Vec<D>) {
        for record in records.drain(..) {
            self.give(record);
        }
    }

    /// Sends an incoming message batch onward (the forwarding idiom of
    /// no-op and map-like operators).
    ///
    /// A uniquely owned batch headed for a single pipeline channel is
    /// handed off **whole** — the lease becomes the outgoing message with
    /// zero per-record work ([`OutputHandle::try_forward`]). Otherwise
    /// owned batches move their records and shared ones clone them out,
    /// record by record.
    pub fn give_batch(&mut self, batch: Batch<D>) {
        match batch {
            Batch::Owned(lease) => {
                if let Err(lease) = self.output.try_forward(&self.time, lease) {
                    for record in Batch::Owned(lease) {
                        self.give(record);
                    }
                }
            }
            shared => {
                for record in shared {
                    self.give(record);
                }
            }
        }
    }

    /// The session timestamp.
    pub fn time(&self) -> &T {
        &self.time
    }
}

impl<'a, T: Timestamp, D: Data> Drop for Session<'a, T, D> {
    fn drop(&mut self) {
        self.output.flush(&self.time);
    }
}

/// Low-level operator construction.
pub struct OperatorBuilder<T: Timestamp> {
    scope: Scope<T>,
    node: usize,
    inputs: usize,
    outputs: usize,
    /// Input queues (for the scheduler's work hint).
    queues: Vec<Box<dyn Fn() -> bool>>,
    /// Input frontier handles (scheduling triggers + tracker adoption).
    frontiers: Vec<FrontierHandle<T>>,
    /// Deferred internal-summary overrides: (input, output, summary).
    summaries: Vec<(usize, usize, T::Summary)>,
}

impl<T: Timestamp> OperatorBuilder<T> {
    /// Registers a new node named `name` and returns its builder.
    pub fn new(scope: &Scope<T>, name: &str) -> Self {
        let mut state = scope.state.borrow_mut();
        assert!(!state.finalized, "cannot add operators after the dataflow started");
        let node = state.topology.nodes.len();
        state.topology.nodes.push(NodeTopology::identity(name, 0, 0));
        drop(state);
        OperatorBuilder {
            scope: scope.clone(),
            node,
            inputs: 0,
            outputs: 0,
            queues: Vec::new(),
            frontiers: Vec::new(),
            summaries: Vec::new(),
        }
    }

    /// The node index of the operator under construction.
    pub fn node(&self) -> usize {
        self.node
    }

    /// Adds an input port fed by `stream` under `pact`; returns the local
    /// mailbox and the port's frontier handle.
    pub fn new_input<D: Data>(
        &mut self,
        stream: &Stream<T, D>,
        pact: Pact<D>,
    ) -> (LocalQueue<T, D>, FrontierHandle<T>, usize) {
        let (queue, frontier, port) = self.new_input_deferred::<D>();
        stream.connect_to(self.node, port, pact, queue.clone());
        (queue, frontier, port)
    }

    /// Adds an input port with no producer yet (feedback edges connect
    /// later); returns the mailbox, frontier handle, and port index.
    pub fn new_input_deferred<D: Data>(
        &mut self,
    ) -> (LocalQueue<T, D>, FrontierHandle<T>, usize) {
        let port = self.inputs;
        self.inputs += 1;
        let queue: LocalQueue<T, D> = Rc::new(RefCell::new(VecDeque::new()));
        let frontier: FrontierHandle<T> = Rc::new(RefCell::new(SharedFrontier {
            antichain: MutableAntichain::new(),
            changed: false,
        }));
        let mut state = self.scope.state.borrow_mut();
        state.frontier_handles.push((self.node, port, frontier.clone()));
        drop(state);
        let q = queue.clone();
        self.queues.push(Box::new(move || !q.borrow().is_empty()));
        self.frontiers.push(frontier.clone());
        (queue, frontier, port)
    }

    /// Adds an output port; returns its tee and the downstream stream.
    pub fn new_output<D: Data>(&mut self) -> (TeeHandle<T, D>, Stream<T, D>) {
        let port = self.outputs;
        self.outputs += 1;
        let tee: TeeHandle<T, D> = Rc::new(RefCell::new(Vec::new()));
        let stream = Stream::new(Location::source(self.node, port), tee.clone(), self.scope.clone());
        (tee, stream)
    }

    /// Overrides the internal summary from `input` to `output` (the default
    /// is the identity for every pair). Feedback uses a strictly advancing
    /// summary.
    pub fn set_summary(&mut self, input: usize, output: usize, summary: T::Summary) {
        self.summaries.push((input, output, summary));
    }

    /// Mints the operator's initial timestamp tokens — one per output port
    /// at `T::minimum()`, pre-counted by the tracker's seed.
    pub fn initial_tokens(&self) -> Vec<TimestampToken<T>> {
        let bookkeeping = self.scope.bookkeeping();
        (0..self.outputs)
            .map(|port| {
                TimestampToken::mint_preseeded(
                    T::minimum(),
                    Location::source(self.node, port),
                    bookkeeping.clone(),
                )
            })
            .collect()
    }

    /// The activator and info for the operator under construction.
    pub fn info(&self) -> (OperatorInfo, Rc<Cell<bool>>) {
        let flag = Rc::new(Cell::new(true)); // run once at startup
        let info = OperatorInfo {
            node: self.node,
            worker: self.scope.index(),
            peers: self.scope.peers(),
            activator: Activator::new(flag.clone()),
        };
        (info, flag)
    }

    /// Registers the operator logic with the worker's scheduler.
    pub fn build(self, activation: Rc<Cell<bool>>, logic: Box<dyn FnMut()>) {
        let mut state = self.scope.state.borrow_mut();
        // Fix up the node topology with the real port counts and summaries.
        let mut topo = NodeTopology::<T>::identity(
            &state.topology.nodes[self.node].name.clone(),
            self.inputs,
            self.outputs,
        );
        for (i, o, s) in self.summaries {
            topo.internal[i][o] = crate::progress::antichain::Antichain::from_elem(s);
        }
        let name = topo.name.clone();
        state.topology.nodes[self.node] = topo;
        let queues = self.queues;
        state.ops.push(OpCore {
            name,
            node: self.node,
            logic,
            work_hint: Box::new(move || queues.iter().any(|q| q())),
            activation,
            frontiers: self.frontiers,
        });
    }
}

/// High-level operator constructors on streams.
pub trait OperatorExt<T: Timestamp, D: Data> {
    /// A unary operator that only reacts to data (map/filter-like): the
    /// constructor receives the initial token and operator info, and returns
    /// logic invoked with the input and output handles.
    fn unary<D2: Data, B, L>(&self, pact: Pact<D>, name: &str, constructor: B) -> Stream<T, D2>
    where
        B: FnOnce(TimestampToken<T>, OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>, &mut OutputHandle<T, D2>) + 'static;

    /// Like [`unary`](OperatorExt::unary); the name matches the paper's
    /// Figure 5 (`unary_frontier`) — the input handle exposes
    /// `input.frontier()` and the operator is scheduled on frontier changes.
    fn unary_frontier<D2: Data, B, L>(
        &self,
        pact: Pact<D>,
        name: &str,
        constructor: B,
    ) -> Stream<T, D2>
    where
        B: FnOnce(TimestampToken<T>, OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>, &mut OutputHandle<T, D2>) + 'static,
    {
        self.unary(pact, name, constructor)
    }

    /// A two-input operator.
    fn binary_frontier<D2: Data, D3: Data, B, L>(
        &self,
        other: &Stream<T, D2>,
        pact1: Pact<D>,
        pact2: Pact<D2>,
        name: &str,
        constructor: B,
    ) -> Stream<T, D3>
    where
        B: FnOnce(TimestampToken<T>, OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>, &mut InputHandle<T, D2>, &mut OutputHandle<T, D3>)
            + 'static;

    /// A terminal operator: consumes batches, produces nothing.
    fn sink<B, L>(&self, pact: Pact<D>, name: &str, constructor: B)
    where
        B: FnOnce(OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>) + 'static;
}

impl<T: Timestamp, D: Data> OperatorExt<T, D> for Stream<T, D> {
    fn unary<D2: Data, B, L>(&self, pact: Pact<D>, name: &str, constructor: B) -> Stream<T, D2>
    where
        B: FnOnce(TimestampToken<T>, OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>, &mut OutputHandle<T, D2>) + 'static,
    {
        let scope = self.scope();
        let mut builder = OperatorBuilder::new(&scope, name);
        let (queue, frontier, _port) = builder.new_input(self, pact);
        let (tee, stream) = builder.new_output::<D2>();
        let (info, activation) = builder.info();
        let node = builder.node();
        let bookkeeping = scope.bookkeeping();
        let batch_size = scope.send_batch();
        let mut init = builder.initial_tokens();
        let mut logic = constructor(init.pop().expect("one output"), info.clone());
        let mut input = InputHandle::new(
            queue,
            frontier,
            Location::target(node, 0),
            Some(Location::source(node, 0)),
            T::Summary::default(),
            bookkeeping.clone(),
        );
        let mut output = OutputHandle::new(
            Location::source(node, 0),
            tee,
            bookkeeping,
            info.worker,
            info.peers,
            batch_size,
        );
        let tracer = scope.tracer();
        input.set_tracer(tracer.clone());
        output.set_tracer(tracer);
        builder.build(activation, Box::new(move || logic(&mut input, &mut output)));
        stream
    }

    fn binary_frontier<D2: Data, D3: Data, B, L>(
        &self,
        other: &Stream<T, D2>,
        pact1: Pact<D>,
        pact2: Pact<D2>,
        name: &str,
        constructor: B,
    ) -> Stream<T, D3>
    where
        B: FnOnce(TimestampToken<T>, OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>, &mut InputHandle<T, D2>, &mut OutputHandle<T, D3>)
            + 'static,
    {
        let scope = self.scope();
        let mut builder = OperatorBuilder::new(&scope, name);
        let (queue1, frontier1, _p1) = builder.new_input(self, pact1);
        let (queue2, frontier2, _p2) = builder.new_input(other, pact2);
        let (tee, stream) = builder.new_output::<D3>();
        let (info, activation) = builder.info();
        let node = builder.node();
        let bookkeeping = scope.bookkeeping();
        let batch_size = scope.send_batch();
        let mut init = builder.initial_tokens();
        let mut logic = constructor(init.pop().expect("one output"), info.clone());
        let mut input1 = InputHandle::new(
            queue1,
            frontier1,
            Location::target(node, 0),
            Some(Location::source(node, 0)),
            T::Summary::default(),
            bookkeeping.clone(),
        );
        let mut input2 = InputHandle::new(
            queue2,
            frontier2,
            Location::target(node, 1),
            Some(Location::source(node, 0)),
            T::Summary::default(),
            bookkeeping.clone(),
        );
        let mut output = OutputHandle::new(
            Location::source(node, 0),
            tee,
            bookkeeping,
            info.worker,
            info.peers,
            batch_size,
        );
        let tracer = scope.tracer();
        input1.set_tracer(tracer.clone());
        input2.set_tracer(tracer.clone());
        output.set_tracer(tracer);
        builder.build(
            activation,
            Box::new(move || logic(&mut input1, &mut input2, &mut output)),
        );
        stream
    }

    fn sink<B, L>(&self, pact: Pact<D>, name: &str, constructor: B)
    where
        B: FnOnce(OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>) + 'static,
    {
        let scope = self.scope();
        let mut builder = OperatorBuilder::new(&scope, name);
        let (queue, frontier, _port) = builder.new_input(self, pact);
        let (info, activation) = builder.info();
        let node = builder.node();
        let bookkeeping = scope.bookkeeping();
        let mut logic = constructor(info);
        let mut input = InputHandle::new(
            queue,
            frontier,
            Location::target(node, 0),
            None,
            T::Summary::default(),
            bookkeeping,
        );
        input.set_tracer(scope.tracer());
        builder.build(activation, Box::new(move || logic(&mut input)));
    }
}
