//! Operator construction: the API of the paper's Figure 5.
//!
//! [`OperatorBuilder`] is the low-level interface; [`OperatorExt`] provides
//! `unary`, `unary_frontier`, and `binary_frontier`, whose constructors
//! receive the operator's initial [`TimestampToken`] (§3.1: "each dataflow
//! operator is initially provided with a timestamp token for each of its
//! output edges") and return the repeatedly invoked operator logic.

use super::channels::{Data, LocalQueue, Message, Pact, Route, TeeHandle};
use super::scope::{Activator, OpCore, Scope};
use super::stream::Stream;
use super::token::{BookkeepingHandle, TimestampToken, TimestampTokenRef, TokenTrait};
use crate::progress::antichain::MutableAntichain;
use crate::progress::location::Location;
use crate::progress::reachability::NodeTopology;
use crate::progress::timestamp::{PathSummary, Timestamp};
use crate::progress::tracker::{FrontierHandle, SharedFrontier};
use std::cell::{Cell, Ref, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;

/// Static facts about an operator instance, handed to its constructor.
#[derive(Clone)]
pub struct OperatorInfo {
    /// The node index in the dataflow graph.
    pub node: usize,
    /// This worker's index.
    pub worker: usize,
    /// Total number of workers.
    pub peers: usize,
    /// Re-scheduling handle (co-operative flow control, §6.1).
    pub activator: Activator,
}

/// The read side of one operator input port.
///
/// Yields `(TimestampTokenRef, batch)` pairs — each message batch arrives
/// "bearing a timestamp token that can be used by the recipient" (§4.1) —
/// and exposes the port's frontier as maintained by the tracker.
pub struct InputHandle<T: Timestamp, D: Data> {
    queue: LocalQueue<T, D>,
    frontier: FrontierHandle<T>,
    target: Location,
    /// Where a retained token would live (`None` for output-less operators).
    retain_location: Option<Location>,
    /// The internal summary from this input to output 0 (identity for
    /// ordinary operators; strictly advancing for feedback).
    retain_summary: T::Summary,
    bookkeeping: BookkeepingHandle<T>,
}

impl<T: Timestamp, D: Data> InputHandle<T, D> {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        queue: LocalQueue<T, D>,
        frontier: FrontierHandle<T>,
        target: Location,
        retain_location: Option<Location>,
        retain_summary: T::Summary,
        bookkeeping: BookkeepingHandle<T>,
    ) -> Self {
        InputHandle { queue, frontier, target, retain_location, retain_summary, bookkeeping }
    }

    /// Pops the next message batch, recording its consumption with the
    /// system. The returned token reference cannot outlive the read — call
    /// [`TimestampTokenRef::retain`] to keep a token.
    pub fn next(&mut self) -> Option<(TimestampTokenRef<'_, T>, Vec<D>)> {
        let message = self.queue.borrow_mut().pop_front()?;
        let Message { time, data, .. } = message;
        self.bookkeeping.update(self.target, time.clone(), -1);
        let cap_time = self
            .retain_summary
            .results_in(&time)
            .expect("internal summary overflowed the timestamp domain");
        Some((
            TimestampTokenRef::new(time, cap_time, self.retain_location, &self.bookkeeping),
            data,
        ))
    }

    /// Applies `logic` to every queued batch.
    pub fn for_each<L: FnMut(TimestampTokenRef<'_, T>, Vec<D>)>(&mut self, mut logic: L) {
        while let Some((token, data)) = self.next() {
            logic(token, data);
        }
    }

    /// The port's current frontier — the lower bound on timestamps that may
    /// still appear on this input (§3.2).
    pub fn frontier(&self) -> Ref<'_, MutableAntichain<T>> {
        Ref::map(self.frontier.borrow(), |shared| &shared.antichain)
    }

    /// True iff the frontier has passed `t` (no more data at `t` or earlier
    /// can arrive).
    pub fn frontier_beyond(&self, t: &T) -> bool {
        !self.frontier.borrow().antichain.less_equal(t)
    }

    /// True iff the input is complete (closed frontier, empty queue).
    pub fn is_done(&self) -> bool {
        self.frontier.borrow().antichain.is_empty() && self.queue.borrow().is_empty()
    }
}

/// The write side of one operator output port (Ⓗ in the paper's Figure 3).
pub struct OutputHandle<T: Timestamp, D: Data> {
    source: Location,
    tee: TeeHandle<T, D>,
    bookkeeping: BookkeepingHandle<T>,
    peers: usize,
    worker: usize,
    /// Per-channel, per-destination buffers reused across sessions.
    buffers: Vec<Vec<Vec<D>>>,
    /// Pact snapshot aligned with `tee` (channels only ever append).
    pacts: Vec<Pact<D>>,
}

impl<T: Timestamp, D: Data> OutputHandle<T, D> {
    pub(crate) fn new(
        source: Location,
        tee: TeeHandle<T, D>,
        bookkeeping: BookkeepingHandle<T>,
        worker: usize,
        peers: usize,
    ) -> Self {
        OutputHandle { source, tee, bookkeeping, peers, worker, buffers: Vec::new(), pacts: Vec::new() }
    }

    /// Obtains a session that can send data at the timestamp associated with
    /// timestamp token `tok` (Ⓘ). Accepts owned tokens and token references
    /// alike ([`TokenTrait`]); the token's location is checked against this
    /// output.
    ///
    /// The borrow of `tok` ensures at compile time that the token cannot be
    /// modified or dropped while the session is active.
    pub fn session<'a>(&'a mut self, tok: &'a impl TokenTrait<T>) -> Session<'a, T, D> {
        if let Some(location) = tok.session_location() {
            assert_eq!(
                location, self.source,
                "timestamp token is not valid for this output"
            );
        }
        let time = tok.session_time().clone();
        Session { output: self, time }
    }

    /// Refreshes the pact snapshot (channels may attach after construction).
    fn ensure_buffers(&mut self) {
        let tee = self.tee.borrow();
        while self.pacts.len() < tee.len() {
            self.pacts.push(tee[self.pacts.len()].borrow().pact.clone());
            self.buffers.push(vec![Vec::new(); self.peers]);
        }
    }

    /// Routes one record into the per-channel/per-destination buffers.
    fn give(&mut self, time: &T, record: D) {
        self.ensure_buffers();
        for ci in 0..self.pacts.len() {
            match &self.pacts[ci] {
                Pact::Pipeline => {
                    let dest = self.worker;
                    self.buffers[ci][dest].push(record.clone());
                    if self.buffers[ci][dest].len() >= crate::config::SEND_BATCH {
                        self.post(ci, dest, time);
                    }
                }
                Pact::Exchange(route) => match route(&record) {
                    Route::Worker(hash) => {
                        let dest = (hash % self.peers as u64) as usize;
                        self.buffers[ci][dest].push(record.clone());
                        if self.buffers[ci][dest].len() >= crate::config::SEND_BATCH {
                            self.post(ci, dest, time);
                        }
                    }
                    Route::All => {
                        for dest in 0..self.peers {
                            self.buffers[ci][dest].push(record.clone());
                            if self.buffers[ci][dest].len() >= crate::config::SEND_BATCH {
                                self.post(ci, dest, time);
                            }
                        }
                    }
                },
            }
        }
    }

    /// Finalizes a batch: records `+1` at the channel target and enqueues
    /// the message (local mailboxes immediately; remote staged until the
    /// worker's progress append).
    fn post(&mut self, ci: usize, dest: usize, time: &T) {
        let data = std::mem::take(&mut self.buffers[ci][dest]);
        if data.is_empty() {
            return;
        }
        let tee = self.tee.borrow();
        let mut channel = tee[ci].borrow_mut();
        self.bookkeeping.update(channel.target, time.clone(), 1);
        channel.push(dest, Message { time: time.clone(), data, from: self.worker });
    }

    /// Flushes all buffered records at `time`.
    fn flush(&mut self, time: &T) {
        self.ensure_buffers();
        for ci in 0..self.pacts.len() {
            for dest in 0..self.peers {
                if !self.buffers[ci][dest].is_empty() {
                    self.post(ci, dest, time);
                }
            }
        }
    }
}

/// An active output session at a fixed timestamp; created from a timestamp
/// token by [`OutputHandle::session`]. Buffers records and flushes them as
/// message batches when dropped.
pub struct Session<'a, T: Timestamp, D: Data> {
    output: &'a mut OutputHandle<T, D>,
    time: T,
}

impl<'a, T: Timestamp, D: Data> Session<'a, T, D> {
    /// Sends one record at the session timestamp.
    #[inline]
    pub fn give(&mut self, record: D) {
        self.output.give(&self.time, record);
    }

    /// Sends every record of an iterator.
    pub fn give_iterator<I: Iterator<Item = D>>(&mut self, iter: I) {
        for record in iter {
            self.give(record);
        }
    }

    /// Sends a vector of records.
    pub fn give_vec(&mut self, mut records: Vec<D>) {
        for record in records.drain(..) {
            self.give(record);
        }
    }

    /// The session timestamp.
    pub fn time(&self) -> &T {
        &self.time
    }
}

impl<'a, T: Timestamp, D: Data> Drop for Session<'a, T, D> {
    fn drop(&mut self) {
        self.output.flush(&self.time);
    }
}

/// Low-level operator construction.
pub struct OperatorBuilder<T: Timestamp> {
    scope: Scope<T>,
    node: usize,
    inputs: usize,
    outputs: usize,
    /// Input queues (for the scheduler's work hint).
    queues: Vec<Box<dyn Fn() -> bool>>,
    /// Input frontier handles (scheduling triggers + tracker adoption).
    frontiers: Vec<FrontierHandle<T>>,
    /// Deferred internal-summary overrides: (input, output, summary).
    summaries: Vec<(usize, usize, T::Summary)>,
}

impl<T: Timestamp> OperatorBuilder<T> {
    /// Registers a new node named `name` and returns its builder.
    pub fn new(scope: &Scope<T>, name: &str) -> Self {
        let mut state = scope.state.borrow_mut();
        assert!(!state.finalized, "cannot add operators after the dataflow started");
        let node = state.topology.nodes.len();
        state.topology.nodes.push(NodeTopology::identity(name, 0, 0));
        drop(state);
        OperatorBuilder {
            scope: scope.clone(),
            node,
            inputs: 0,
            outputs: 0,
            queues: Vec::new(),
            frontiers: Vec::new(),
            summaries: Vec::new(),
        }
    }

    /// The node index of the operator under construction.
    pub fn node(&self) -> usize {
        self.node
    }

    /// Adds an input port fed by `stream` under `pact`; returns the local
    /// mailbox and the port's frontier handle.
    pub fn new_input<D: Data>(
        &mut self,
        stream: &Stream<T, D>,
        pact: Pact<D>,
    ) -> (LocalQueue<T, D>, FrontierHandle<T>, usize) {
        let (queue, frontier, port) = self.new_input_deferred::<D>();
        stream.connect_to(self.node, port, pact, queue.clone());
        (queue, frontier, port)
    }

    /// Adds an input port with no producer yet (feedback edges connect
    /// later); returns the mailbox, frontier handle, and port index.
    pub fn new_input_deferred<D: Data>(
        &mut self,
    ) -> (LocalQueue<T, D>, FrontierHandle<T>, usize) {
        let port = self.inputs;
        self.inputs += 1;
        let queue: LocalQueue<T, D> = Rc::new(RefCell::new(VecDeque::new()));
        let frontier: FrontierHandle<T> = Rc::new(RefCell::new(SharedFrontier {
            antichain: MutableAntichain::new(),
            changed: false,
        }));
        let mut state = self.scope.state.borrow_mut();
        state.frontier_handles.push((self.node, port, frontier.clone()));
        drop(state);
        let q = queue.clone();
        self.queues.push(Box::new(move || !q.borrow().is_empty()));
        self.frontiers.push(frontier.clone());
        (queue, frontier, port)
    }

    /// Adds an output port; returns its tee and the downstream stream.
    pub fn new_output<D: Data>(&mut self) -> (TeeHandle<T, D>, Stream<T, D>) {
        let port = self.outputs;
        self.outputs += 1;
        let tee: TeeHandle<T, D> = Rc::new(RefCell::new(Vec::new()));
        let stream = Stream::new(Location::source(self.node, port), tee.clone(), self.scope.clone());
        (tee, stream)
    }

    /// Overrides the internal summary from `input` to `output` (the default
    /// is the identity for every pair). Feedback uses a strictly advancing
    /// summary.
    pub fn set_summary(&mut self, input: usize, output: usize, summary: T::Summary) {
        self.summaries.push((input, output, summary));
    }

    /// Mints the operator's initial timestamp tokens — one per output port
    /// at `T::minimum()`, pre-counted by the tracker's seed.
    pub fn initial_tokens(&self) -> Vec<TimestampToken<T>> {
        let bookkeeping = self.scope.bookkeeping();
        (0..self.outputs)
            .map(|port| {
                TimestampToken::mint_preseeded(
                    T::minimum(),
                    Location::source(self.node, port),
                    bookkeeping.clone(),
                )
            })
            .collect()
    }

    /// The activator and info for the operator under construction.
    pub fn info(&self) -> (OperatorInfo, Rc<Cell<bool>>) {
        let flag = Rc::new(Cell::new(true)); // run once at startup
        let info = OperatorInfo {
            node: self.node,
            worker: self.scope.index(),
            peers: self.scope.peers(),
            activator: Activator::new(flag.clone()),
        };
        (info, flag)
    }

    /// Registers the operator logic with the worker's scheduler.
    pub fn build(self, activation: Rc<Cell<bool>>, logic: Box<dyn FnMut()>) {
        let mut state = self.scope.state.borrow_mut();
        // Fix up the node topology with the real port counts and summaries.
        let mut topo = NodeTopology::<T>::identity(
            &state.topology.nodes[self.node].name.clone(),
            self.inputs,
            self.outputs,
        );
        for (i, o, s) in self.summaries {
            topo.internal[i][o] = crate::progress::antichain::Antichain::from_elem(s);
        }
        let name = topo.name.clone();
        state.topology.nodes[self.node] = topo;
        let queues = self.queues;
        state.ops.push(OpCore {
            name,
            node: self.node,
            logic,
            work_hint: Box::new(move || queues.iter().any(|q| q())),
            activation,
            frontiers: self.frontiers,
        });
    }
}

/// High-level operator constructors on streams.
pub trait OperatorExt<T: Timestamp, D: Data> {
    /// A unary operator that only reacts to data (map/filter-like): the
    /// constructor receives the initial token and operator info, and returns
    /// logic invoked with the input and output handles.
    fn unary<D2: Data, B, L>(&self, pact: Pact<D>, name: &str, constructor: B) -> Stream<T, D2>
    where
        B: FnOnce(TimestampToken<T>, OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>, &mut OutputHandle<T, D2>) + 'static;

    /// Like [`unary`](OperatorExt::unary); the name matches the paper's
    /// Figure 5 (`unary_frontier`) — the input handle exposes
    /// `input.frontier()` and the operator is scheduled on frontier changes.
    fn unary_frontier<D2: Data, B, L>(
        &self,
        pact: Pact<D>,
        name: &str,
        constructor: B,
    ) -> Stream<T, D2>
    where
        B: FnOnce(TimestampToken<T>, OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>, &mut OutputHandle<T, D2>) + 'static,
    {
        self.unary(pact, name, constructor)
    }

    /// A two-input operator.
    fn binary_frontier<D2: Data, D3: Data, B, L>(
        &self,
        other: &Stream<T, D2>,
        pact1: Pact<D>,
        pact2: Pact<D2>,
        name: &str,
        constructor: B,
    ) -> Stream<T, D3>
    where
        B: FnOnce(TimestampToken<T>, OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>, &mut InputHandle<T, D2>, &mut OutputHandle<T, D3>)
            + 'static;

    /// A terminal operator: consumes batches, produces nothing.
    fn sink<B, L>(&self, pact: Pact<D>, name: &str, constructor: B)
    where
        B: FnOnce(OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>) + 'static;
}

impl<T: Timestamp, D: Data> OperatorExt<T, D> for Stream<T, D> {
    fn unary<D2: Data, B, L>(&self, pact: Pact<D>, name: &str, constructor: B) -> Stream<T, D2>
    where
        B: FnOnce(TimestampToken<T>, OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>, &mut OutputHandle<T, D2>) + 'static,
    {
        let scope = self.scope();
        let mut builder = OperatorBuilder::new(&scope, name);
        let (queue, frontier, _port) = builder.new_input(self, pact);
        let (tee, stream) = builder.new_output::<D2>();
        let (info, activation) = builder.info();
        let node = builder.node();
        let bookkeeping = scope.bookkeeping();
        let mut init = builder.initial_tokens();
        let mut logic = constructor(init.pop().expect("one output"), info.clone());
        let mut input = InputHandle::new(
            queue,
            frontier,
            Location::target(node, 0),
            Some(Location::source(node, 0)),
            T::Summary::default(),
            bookkeeping.clone(),
        );
        let mut output =
            OutputHandle::new(Location::source(node, 0), tee, bookkeeping, info.worker, info.peers);
        builder.build(activation, Box::new(move || logic(&mut input, &mut output)));
        stream
    }

    fn binary_frontier<D2: Data, D3: Data, B, L>(
        &self,
        other: &Stream<T, D2>,
        pact1: Pact<D>,
        pact2: Pact<D2>,
        name: &str,
        constructor: B,
    ) -> Stream<T, D3>
    where
        B: FnOnce(TimestampToken<T>, OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>, &mut InputHandle<T, D2>, &mut OutputHandle<T, D3>)
            + 'static,
    {
        let scope = self.scope();
        let mut builder = OperatorBuilder::new(&scope, name);
        let (queue1, frontier1, _p1) = builder.new_input(self, pact1);
        let (queue2, frontier2, _p2) = builder.new_input(other, pact2);
        let (tee, stream) = builder.new_output::<D3>();
        let (info, activation) = builder.info();
        let node = builder.node();
        let bookkeeping = scope.bookkeeping();
        let mut init = builder.initial_tokens();
        let mut logic = constructor(init.pop().expect("one output"), info.clone());
        let mut input1 = InputHandle::new(
            queue1,
            frontier1,
            Location::target(node, 0),
            Some(Location::source(node, 0)),
            T::Summary::default(),
            bookkeeping.clone(),
        );
        let mut input2 = InputHandle::new(
            queue2,
            frontier2,
            Location::target(node, 1),
            Some(Location::source(node, 0)),
            T::Summary::default(),
            bookkeeping.clone(),
        );
        let mut output =
            OutputHandle::new(Location::source(node, 0), tee, bookkeeping, info.worker, info.peers);
        builder.build(
            activation,
            Box::new(move || logic(&mut input1, &mut input2, &mut output)),
        );
        stream
    }

    fn sink<B, L>(&self, pact: Pact<D>, name: &str, constructor: B)
    where
        B: FnOnce(OperatorInfo) -> L,
        L: FnMut(&mut InputHandle<T, D>) + 'static,
    {
        let scope = self.scope();
        let mut builder = OperatorBuilder::new(&scope, name);
        let (queue, frontier, _port) = builder.new_input(self, pact);
        let (info, activation) = builder.info();
        let node = builder.node();
        let bookkeeping = scope.bookkeeping();
        let mut logic = constructor(info);
        let mut input = InputHandle::new(
            queue,
            frontier,
            Location::target(node, 0),
            None,
            T::Summary::default(),
            bookkeeping,
        );
        builder.build(activation, Box::new(move || logic(&mut input)));
    }
}
