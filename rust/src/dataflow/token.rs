//! Timestamp tokens — the paper's coordination primitive (§3, §4, Figure 3).
//!
//! A [`TimestampToken`] is an in-memory object that names a pointstamp
//! `(t, l)` and grants its holder the ability to produce messages with
//! timestamp `t` at location `l` (an operator output port). The system is
//! informed of *net changes* to the number of tokens at each pointstamp,
//! passively, through a bookkeeping structure shared with the worker — never
//! by interposing on each action as a gatekeeper.
//!
//! The three ways user code can change the token count at a pointstamp are
//! exactly those of the paper's Figure 3: [`TimestampToken::downgrade`]
//! (Ⓔ), `Clone` (Ⓕ), and `Drop` (Ⓖ). Messages received from an input carry
//! a [`TimestampTokenRef`] (§4.2) that cannot outlive the read and must be
//! explicitly [`TimestampTokenRef::retain`]ed to obtain an owned token —
//! this is what keeps operators from accidentally capturing and holding a
//! token forever.

use crate::progress::change_batch::ChangeBatch;
use crate::progress::location::Location;
use crate::progress::timestamp::{PartialOrder, Timestamp};
use std::cell::RefCell;
use std::fmt::Debug;
use std::rc::Rc;

/// The bookkeeping structure shared between tokens and the host worker
/// (field Ⓒ of the paper's Figure 3).
///
/// Token methods record `((location, time), ±1)` updates here; the worker
/// drains the batch *after* operator logic yields, so each drained prefix
/// reflects atomic operator actions (§4: "the timely dataflow system drains
/// shared bookkeeping data structures outside of operator logic but on the
/// same thread of control").
#[derive(Clone)]
pub struct BookkeepingHandle<T: Timestamp> {
    changes: Rc<RefCell<ChangeBatch<(Location, T)>>>,
}

impl<T: Timestamp> BookkeepingHandle<T> {
    /// Creates a fresh (empty) bookkeeping structure.
    pub fn new() -> Self {
        BookkeepingHandle { changes: Rc::new(RefCell::new(ChangeBatch::new())) }
    }

    /// Records a count change at a pointstamp.
    #[inline]
    pub fn update(&self, location: Location, time: T, diff: i64) {
        self.changes.borrow_mut().update((location, time), diff);
    }

    /// Drains the accumulated net changes into `into`.
    pub fn drain_into(&self, into: &mut Vec<((Location, T), i64)>) {
        let mut changes = self.changes.borrow_mut();
        into.extend(changes.drain());
    }

    /// True iff no net changes are pending.
    pub fn is_empty(&self) -> bool {
        self.changes.borrow_mut().is_empty()
    }
}

impl<T: Timestamp> Default for BookkeepingHandle<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// The ability to send data with a certain timestamp on a dataflow edge
/// (the paper's Figure 3, Ⓐ).
///
/// Private fields: operator code cannot access or mutate the timestamp or
/// the bookkeeping directly — only through `time`, `downgrade`, `clone` and
/// `drop`, each of which keeps the system's pointstamp counts consistent.
pub struct TimestampToken<T: Timestamp> {
    /// The wrapped timestamp (Ⓑ).
    time: T,
    /// The output port this token is valid for.
    location: Location,
    /// Shared bookkeeping (Ⓒ).
    bookkeeping: BookkeepingHandle<T>,
}

impl<T: Timestamp> TimestampToken<T> {
    /// Mints a token and records `+1` at its pointstamp.
    ///
    /// Crate-internal: user code cannot fabricate tokens (§4: "users cannot
    /// fabricate timestamp tokens outside of unsafe code").
    pub(crate) fn mint(time: T, location: Location, bookkeeping: BookkeepingHandle<T>) -> Self {
        bookkeeping.update(location, time.clone(), 1);
        TimestampToken { time, location, bookkeeping }
    }

    /// Mints a token *without* recording `+1` — used only for the initial
    /// tokens whose counts the tracker pre-seeds (one per output per worker).
    pub(crate) fn mint_preseeded(
        time: T,
        location: Location,
        bookkeeping: BookkeepingHandle<T>,
    ) -> Self {
        TimestampToken { time, location, bookkeeping }
    }

    /// The timestamp associated with this timestamp token (Ⓓ).
    #[inline]
    pub fn time(&self) -> &T {
        &self.time
    }

    /// The location (output port) this token is valid for.
    #[inline]
    pub fn location(&self) -> Location {
        self.location
    }

    /// Downgrades the timestamp token to one corresponding to `new_time`
    /// (Ⓔ). This reduces the holder's ability to produce output at the
    /// wrapped timestamp, potentially unblocking downstream operators.
    ///
    /// Panics if `new_time` is not greater than or equal to the current
    /// timestamp — tokens can only move *forward*.
    pub fn downgrade(&mut self, new_time: &T) {
        assert!(
            self.time.less_equal(new_time),
            "token downgrade must advance the timestamp: {:?} -> {:?}",
            self.time,
            new_time
        );
        if &self.time != new_time {
            self.bookkeeping.update(self.location, new_time.clone(), 1);
            self.bookkeeping.update(self.location, self.time.clone(), -1);
            self.time = new_time.clone();
        }
    }

    /// A new token at `new_time ≥ self.time()` (a clone + downgrade).
    pub fn delayed(&self, new_time: &T) -> TimestampToken<T> {
        assert!(
            self.time.less_equal(new_time),
            "delayed token must advance the timestamp: {:?} -> {:?}",
            self.time,
            new_time
        );
        TimestampToken::mint(new_time.clone(), self.location, self.bookkeeping.clone())
    }
}

/// Cloning increments the pointstamp count (Ⓕ).
impl<T: Timestamp> Clone for TimestampToken<T> {
    fn clone(&self) -> TimestampToken<T> {
        TimestampToken::mint(self.time.clone(), self.location, self.bookkeeping.clone())
    }
}

/// Dropping decrements the pointstamp count (Ⓖ). Rust inserts this call
/// eagerly whenever a token goes out of scope, which "makes it much less
/// likely that an operator will fail to release a timestamp token" (§4.1).
impl<T: Timestamp> Drop for TimestampToken<T> {
    fn drop(&mut self) {
        self.bookkeeping.update(self.location, self.time.clone(), -1);
    }
}

impl<T: Timestamp> Debug for TimestampToken<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        f.debug_struct("TimestampToken")
            .field("time", &self.time)
            .field("location", &self.location)
            .finish()
    }
}

/// A borrowed "timestamp token option" (§4.2): delivered alongside each
/// input message batch, it can open output sessions directly but cannot be
/// held beyond the current read — the lifetime ties it to the input handle
/// borrow. Call [`retain`](TimestampTokenRef::retain) to obtain an owned
/// [`TimestampToken`].
pub struct TimestampTokenRef<'a, T: Timestamp> {
    /// The message timestamp.
    time: T,
    /// The capability timestamp for the operator's output (the message time
    /// advanced by the operator's internal summary — identity for ordinary
    /// operators, strictly advancing for feedback).
    cap_time: T,
    /// The output port a retained token would be valid for (if any).
    location: Option<Location>,
    bookkeeping: &'a BookkeepingHandle<T>,
}

impl<'a, T: Timestamp> TimestampTokenRef<'a, T> {
    pub(crate) fn new(
        time: T,
        cap_time: T,
        location: Option<Location>,
        bookkeeping: &'a BookkeepingHandle<T>,
    ) -> Self {
        TimestampTokenRef { time, cap_time, location, bookkeeping }
    }

    /// The timestamp of the message this reference accompanies.
    #[inline]
    pub fn time(&self) -> &T {
        &self.time
    }

    /// Obtains an owned [`TimestampToken`] for the operator's output at the
    /// capability time (§4.2: "to acquire an owned token, user code must
    /// explicitly call retain").
    pub fn retain(&self) -> TimestampToken<T> {
        let location = self
            .location
            .expect("retain() on an operator with no outputs");
        TimestampToken::mint(self.cap_time.clone(), location, self.bookkeeping.clone())
    }
}

impl<'a, T: Timestamp> Debug for TimestampTokenRef<'a, T> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        f.debug_struct("TimestampTokenRef").field("time", &self.time).finish()
    }
}

/// Implemented by both [`TimestampToken`] and [`TimestampTokenRef`], so
/// output sessions accept either (§4.2: "allows users to bypass the retain
/// method and create a Session from a token reference, ... avoiding
/// bookkeeping when timestamp token ownership is not needed").
pub trait TokenTrait<T: Timestamp> {
    /// The timestamp a session opened with this token will send at.
    fn session_time(&self) -> &T;
    /// The output location the token authorizes, if any.
    fn session_location(&self) -> Option<Location>;
}

impl<T: Timestamp> TokenTrait<T> for TimestampToken<T> {
    fn session_time(&self) -> &T {
        &self.time
    }
    fn session_location(&self) -> Option<Location> {
        Some(self.location)
    }
}

impl<'a, T: Timestamp> TokenTrait<T> for TimestampTokenRef<'a, T> {
    fn session_time(&self) -> &T {
        &self.cap_time
    }
    fn session_location(&self) -> Option<Location> {
        self.location
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drained<T: Timestamp>(b: &BookkeepingHandle<T>) -> Vec<((Location, T), i64)> {
        let mut out = Vec::new();
        b.drain_into(&mut out);
        out.sort();
        out
    }

    fn loc() -> Location {
        Location::source(7, 0)
    }

    #[test]
    fn mint_and_drop_balance() {
        let b = BookkeepingHandle::<u64>::new();
        {
            let _tok = TimestampToken::mint(3, loc(), b.clone());
            // +1 pending while held.
        }
        // Net effect after drop: nothing.
        assert!(drained(&b).is_empty());
    }

    #[test]
    fn clone_increments() {
        let b = BookkeepingHandle::<u64>::new();
        let tok = TimestampToken::mint(3, loc(), b.clone());
        let tok2 = tok.clone();
        assert_eq!(drained(&b), vec![((loc(), 3), 2)]);
        drop(tok);
        drop(tok2);
        assert_eq!(drained(&b), vec![((loc(), 3), -2)]);
    }

    #[test]
    fn downgrade_moves_count() {
        let b = BookkeepingHandle::<u64>::new();
        let mut tok = TimestampToken::mint(0, loc(), b.clone());
        drained(&b);
        tok.downgrade(&10);
        assert_eq!(tok.time(), &10);
        assert_eq!(drained(&b), vec![((loc(), 0), -1), ((loc(), 10), 1)]);
        // No-op downgrade to the same time records nothing.
        tok.downgrade(&10);
        assert!(drained(&b).is_empty());
        std::mem::forget(tok); // avoid drop noise in this test
    }

    #[test]
    #[should_panic(expected = "downgrade must advance")]
    fn downgrade_backwards_panics() {
        let b = BookkeepingHandle::<u64>::new();
        let mut tok = TimestampToken::mint(5, loc(), b);
        tok.downgrade(&4);
    }

    #[test]
    fn delayed_mints_new_token() {
        let b = BookkeepingHandle::<u64>::new();
        let tok = TimestampToken::mint(5, loc(), b.clone());
        drained(&b);
        let tok2 = tok.delayed(&8);
        assert_eq!(tok2.time(), &8);
        assert_eq!(tok.time(), &5);
        assert_eq!(drained(&b), vec![((loc(), 8), 1)]);
        std::mem::forget((tok, tok2));
    }

    #[test]
    fn preseeded_token_only_counts_on_drop() {
        let b = BookkeepingHandle::<u64>::new();
        let tok = TimestampToken::mint_preseeded(0, loc(), b.clone());
        assert!(drained(&b).is_empty());
        drop(tok);
        assert_eq!(drained(&b), vec![((loc(), 0), -1)]);
    }

    #[test]
    fn token_ref_retain_mints_at_cap_time() {
        let b = BookkeepingHandle::<u64>::new();
        // Message at 4; operator internal summary advanced it to 5.
        let r = TimestampTokenRef::new(4, 5, Some(loc()), &b);
        assert_eq!(r.time(), &4);
        let tok = r.retain();
        assert_eq!(tok.time(), &5);
        assert_eq!(drained(&b), vec![((loc(), 5), 1)]);
        std::mem::forget(tok);
    }

    #[test]
    fn compacted_churn_is_silent() {
        // A retain immediately followed by a drop nets to zero system
        // interaction — the batching the paper's §3.1 calls out.
        let b = BookkeepingHandle::<u64>::new();
        let r = TimestampTokenRef::new(4, 4, Some(loc()), &b);
        let tok = r.retain();
        drop(tok);
        assert!(drained(&b).is_empty());
    }
}
