//! Data channels: how timestamped message batches move between operators
//! (and workers).
//!
//! Each graph edge (a *channel*) connects one operator output port to one
//! input port, instantiated on every worker. A channel has a *pact*
//! (parallelization contract): [`Pact::Pipeline`] keeps data on the sending
//! worker, [`Pact::Exchange`] routes each record by key (or broadcasts it).
//!
//! Accounting: a message batch sent at timestamp `t` counts `+1` at the
//! channel's target location, recorded by the sender *before* the batch is
//! visible to the receiver; the receiver records `-1` when it consumes the
//! batch. Remote sends are therefore staged and only released by the worker
//! after it has appended its progress batch to the sequenced log (see
//! `worker::Worker::step`), which is what makes every log prefix a
//! conservative view of the outstanding pointstamps.

use crate::progress::location::Location;
use crate::progress::timestamp::Timestamp;
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};

/// Records that can travel on dataflow edges.
pub trait Data: Clone + Send + 'static {}
impl<D: Clone + Send + 'static> Data for D {}

/// A batch of records bearing one timestamp.
#[derive(Clone, Debug)]
pub struct Message<T, D> {
    /// The logical timestamp of every record in the batch.
    pub time: T,
    /// The records.
    pub data: Vec<D>,
    /// The index of the sending worker (diagnostics / tests).
    pub from: usize,
}

/// Where an exchanged record should go.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// To the worker `hash % peers`.
    Worker(u64),
    /// To every worker (used for control records, e.g. Flink-style
    /// watermarks in the `-X` configuration).
    All,
}

/// Parallelization contract for a channel.
#[derive(Clone)]
pub enum Pact<D> {
    /// Records stay on the worker that produced them.
    Pipeline,
    /// Records are routed between workers by the given function.
    Exchange(Rc<dyn Fn(&D) -> Route>),
}

impl<D> Pact<D> {
    /// An exchange pact routing by a hash of the record.
    pub fn exchange<F: Fn(&D) -> u64 + 'static>(key: F) -> Self {
        Pact::Exchange(Rc::new(move |d| Route::Worker(key(d))))
    }

    /// An exchange pact with full routing control (per-record broadcast).
    pub fn routed<F: Fn(&D) -> Route + 'static>(route: F) -> Self {
        Pact::Exchange(Rc::new(route))
    }
}

impl<D> std::fmt::Debug for Pact<D> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        match self {
            Pact::Pipeline => write!(f, "Pipeline"),
            Pact::Exchange(_) => write!(f, "Exchange"),
        }
    }
}

/// The shared local mailbox of a channel instance on one worker: both
/// same-worker sends and the drainers of remote receivers push here; the
/// owning operator's input handle pops.
pub type LocalQueue<T, D> = Rc<RefCell<VecDeque<Message<T, D>>>>;

/// The send side of one channel on one worker.
pub struct ChannelSend<T: Timestamp, D: Data> {
    /// Channel identifier (same on every worker).
    pub channel: usize,
    /// The input port this channel feeds.
    pub target: Location,
    /// Parallelization contract.
    pub pact: Pact<D>,
    /// This worker's index.
    pub my_index: usize,
    /// Total workers.
    pub peers: usize,
    /// Staged remote messages, released by `flush_remote`.
    staged: Vec<(usize, Message<T, D>)>,
    /// Remote senders, one per peer (`None` at `my_index`).
    remote: Vec<Option<Sender<Message<T, D>>>>,
    /// The local mailbox on this worker (for self-sends).
    local: LocalQueue<T, D>,
    /// Worker-wide flag: set when remote data is staged, so the worker
    /// knows it must append its progress batch (with the corresponding
    /// `+1` produce counts) before releasing the fabric this step.
    staged_flag: Rc<Cell<bool>>,
}

impl<T: Timestamp, D: Data> ChannelSend<T, D> {
    /// Assembles the send side from its parts (done by `Stream::connect_to`).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        channel: usize,
        target: Location,
        pact: Pact<D>,
        my_index: usize,
        peers: usize,
        remote: Vec<Option<Sender<Message<T, D>>>>,
        local: LocalQueue<T, D>,
        staged_flag: Rc<Cell<bool>>,
    ) -> Self {
        debug_assert_eq!(remote.len(), peers);
        ChannelSend {
            channel,
            target,
            pact,
            my_index,
            peers,
            staged: Vec::new(),
            remote,
            local,
            staged_flag,
        }
    }

    /// Enqueues a message batch for worker `dest`.
    ///
    /// Local deliveries are immediate (the consume accounting flows through
    /// the same worker's later atomic batches, so ordering is preserved);
    /// remote deliveries are staged until [`flush_remote`].
    ///
    /// [`flush_remote`]: ChannelSend::flush_remote
    pub fn push(&mut self, dest: usize, message: Message<T, D>) {
        if dest == self.my_index {
            self.local.borrow_mut().push_back(message);
        } else {
            self.staged.push((dest, message));
            self.staged_flag.set(true);
        }
    }

    /// Releases staged remote messages into the fabric. Called by the worker
    /// after its progress batch (containing the `+1` produce counts) has
    /// been appended to the sequenced log.
    pub fn flush_remote(&mut self) {
        for (dest, message) in self.staged.drain(..) {
            if let Some(sender) = &self.remote[dest] {
                // A closed receiver means the peer worker has shut down; at
                // that point progress tracking is already complete for the
                // messages it cared about, so dropping is benign.
                let _ = sender.send(message);
            }
        }
    }

    /// True iff remote messages are staged.
    pub fn has_staged(&self) -> bool {
        !self.staged.is_empty()
    }
}

/// Shared handle to a channel's send side.
pub type ChannelSendHandle<T, D> = Rc<RefCell<ChannelSend<T, D>>>;

/// The list of channels attached to one output port (filled lazily as
/// downstream consumers connect).
pub type TeeHandle<T, D> = Rc<RefCell<Vec<ChannelSendHandle<T, D>>>>;

/// Builds a drainer closure that moves messages from a remote receiver into
/// the channel's local mailbox; returns whether any message moved.
pub fn drainer<T: Timestamp, D: Data>(
    receiver: Receiver<Message<T, D>>,
    queue: LocalQueue<T, D>,
) -> Box<dyn FnMut() -> bool> {
    Box::new(move || {
        let mut any = false;
        loop {
            match receiver.try_recv() {
                Ok(message) => {
                    queue.borrow_mut().push_back(message);
                    any = true;
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        any
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn msg(t: u64, data: Vec<u32>) -> Message<u64, u32> {
        Message { time: t, data, from: 0 }
    }

    #[test]
    fn local_push_is_immediate() {
        let local: LocalQueue<u64, u32> = Rc::new(RefCell::new(VecDeque::new()));
        let mut send = ChannelSend::new(
            0,
            Location::target(1, 0),
            Pact::Pipeline,
            0,
            1,
            vec![None],
            local.clone(),
            Rc::new(Cell::new(false)),
        );
        send.push(0, msg(3, vec![1, 2]));
        assert_eq!(local.borrow().len(), 1);
        assert!(!send.has_staged());
    }

    #[test]
    fn remote_push_staged_until_flush() {
        let (tx, rx) = channel();
        let local: LocalQueue<u64, u32> = Rc::new(RefCell::new(VecDeque::new()));
        let flag = Rc::new(Cell::new(false));
        let mut send = ChannelSend::new(
            0,
            Location::target(1, 0),
            Pact::Pipeline,
            0,
            2,
            vec![None, Some(tx)],
            local,
            flag.clone(),
        );
        send.push(1, msg(3, vec![7]));
        assert!(send.has_staged());
        assert!(flag.get(), "staged flag must be raised for remote pushes");
        assert!(rx.try_recv().is_err());
        send.flush_remote();
        assert_eq!(rx.try_recv().unwrap().data, vec![7]);
        assert!(!send.has_staged());
    }

    #[test]
    fn drainer_moves_messages() {
        let (tx, rx) = channel();
        let queue: LocalQueue<u64, u32> = Rc::new(RefCell::new(VecDeque::new()));
        let mut drain = drainer(rx, queue.clone());
        assert!(!drain());
        tx.send(msg(1, vec![1])).unwrap();
        tx.send(msg(2, vec![2])).unwrap();
        assert!(drain());
        assert_eq!(queue.borrow().len(), 2);
        // Disconnect is handled quietly.
        drop(tx);
        assert!(!drain());
    }

    #[test]
    fn pact_exchange_routes() {
        let pact = Pact::exchange(|d: &u64| *d);
        if let Pact::Exchange(route) = &pact {
            assert_eq!(route(&5), Route::Worker(5));
        } else {
            panic!("not exchange");
        }
        let pact = Pact::<u64>::routed(|_| Route::All);
        if let Pact::Exchange(route) = &pact {
            assert_eq!(route(&5), Route::All);
        } else {
            panic!("not exchange");
        }
    }
}
