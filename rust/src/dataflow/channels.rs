//! Data channels: how timestamped message batches move between operators
//! (and workers).
//!
//! Each graph edge (a *channel*) connects one operator output port to one
//! input port, instantiated on every worker. A channel has a *pact*
//! (parallelization contract): [`Pact::Pipeline`] keeps data on the sending
//! worker, [`Pact::Exchange`] routes each record by key (or broadcasts it).
//!
//! Accounting (PR 1's per-worker broadcast protocol): a message batch sent
//! at timestamp `t` counts `+1` at the channel's target location, recorded
//! in the sender's pending progress batch *before* the batch is visible to
//! the receiver; the receiver records `-1` when it consumes the batch.
//! Remote sends are therefore staged here and only released by the worker
//! after it has broadcast that progress batch into every peer's FIFO
//! mailbox (`worker::Worker::step`'s produce-before-data-release rule) —
//! together with per-sender FIFO delivery, this is what makes any
//! interleaving of mailbox deliveries a conservative view of the
//! outstanding pointstamps (see [`crate::progress::exchange`] for the full
//! argument; there is no sequenced log and no global order).
//!
//! The transport is claimed through the
//! [`Fabric`](crate::worker::allocator::Fabric): the same bounded SPSC
//! ring family the progress plane uses ([`crate::worker::ring`]) for
//! same-process peers, and serializing [`crate::net`] endpoints (the
//! [`Wire`] impl on [`Message`] below) for peers in other processes —
//! channel code cannot tell the difference. Batch payloads are pooled
//! [`Batch`]es rather than per-send `Vec`s: point-to-point batches are
//! [`Lease`]s that return their capacity to the producing output's
//! [`BufferPool`](crate::buffer::BufferPool) when the consumer drops them,
//! and broadcast batches are one shared `Arc` cloned per peer instead of
//! `peers` record-by-record copies. A full ring (or net send queue) is
//! backpressure, not an error: messages stay staged (per destination,
//! FIFO) and are retried on the next flush, after the peer drains.
//!
//! On pipeline channels the payload is not only pooled but *forwarded*: a
//! uniquely owned [`Batch::Owned`] arriving at a map/filter-style operator
//! is transformed in place and handed to the next channel whole (see
//! `Session::give_batch` in [`super::operator`]), so in a steady-state
//! pipeline chain the same lease object is the message payload at every
//! hop — zero allocations *and* zero per-record moves.

use crate::buffer::{BufferPool, Lease};
use crate::net::codec::{Wire, WireError, WireReader};
use crate::progress::location::Location;
use crate::progress::timestamp::Timestamp;
use crate::worker::allocator::{FabricReceiver, FabricSender, WorkerStats};
use crate::worker::ring::RingSendError;
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::mpsc::TryRecvError;
use std::sync::Arc;

/// Records that can travel on dataflow edges.
///
/// The [`Wire`] bound is what lets any channel cross a process boundary:
/// workers claim channels for *every* peer, and whether a given pair rides
/// an intra-process ring or the serializing net fabric is decided at claim
/// time — so every record type must be encodable, even in runs that never
/// leave one process. Implementations exist for the primitive types,
/// tuples, `Vec`/`String`/`Option`, and the engine's record types; custom
/// records implement [`Wire`] alongside `Clone`.
pub trait Data: Clone + Send + Wire + 'static {}
impl<D: Clone + Send + Wire + 'static> Data for D {}

/// The payload of one message batch.
///
/// `Owned` batches are exclusively held pooled buffers: consuming them
/// (by-value iteration) moves the records out without cloning, and the
/// buffer's capacity returns to the producing pool on drop — from whichever
/// worker thread consumed it. `Shared` batches back broadcast deliveries:
/// one `Arc`d buffer is cloned per peer (reference count only), and each
/// consumer clones records out as it iterates.
pub enum Batch<D> {
    /// Exclusively owned (point-to-point) batch.
    Owned(Lease<Vec<D>>),
    /// Shared (broadcast) batch.
    Shared(Arc<Vec<D>>),
}

impl<D> Batch<D> {
    /// Wraps a plain vector (un-pooled) — tests and one-off sends.
    pub fn from_vec(records: Vec<D>) -> Self {
        Batch::Owned(Lease::unpooled(records))
    }

    /// The records, as a slice.
    #[inline]
    pub fn as_slice(&self) -> &[D] {
        match self {
            Batch::Owned(lease) => lease.as_slice(),
            Batch::Shared(arc) => arc.as_slice(),
        }
    }

    /// Number of records in the batch.
    #[inline]
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// True iff the batch holds no records.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }

    /// True iff this batch is shared with other consumers (broadcast).
    pub fn is_shared(&self) -> bool {
        matches!(self, Batch::Shared(_))
    }
}

impl<D> std::ops::Deref for Batch<D> {
    type Target = [D];
    #[inline]
    fn deref(&self) -> &[D] {
        self.as_slice()
    }
}

impl<D: Clone> Clone for Batch<D> {
    fn clone(&self) -> Self {
        match self {
            // An owned batch is deep-copied (un-pooled): cloning is rare
            // and must not alias the exclusively held buffer.
            Batch::Owned(lease) => Batch::Owned(Lease::unpooled(lease.to_vec())),
            Batch::Shared(arc) => Batch::Shared(arc.clone()),
        }
    }
}

impl<D: std::fmt::Debug> std::fmt::Debug for Batch<D> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        f.debug_list().entries(self.as_slice().iter()).finish()
    }
}

impl<'a, D> IntoIterator for &'a Batch<D> {
    type Item = &'a D;
    type IntoIter = std::slice::Iter<'a, D>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<D: Clone> IntoIterator for Batch<D> {
    type Item = D;
    type IntoIter = BatchIntoIter<D>;

    /// By-value iteration: moves records out of an `Owned` batch (no
    /// clone; the emptied buffer returns to its pool when the iterator
    /// drops), clones them out of a `Shared` one.
    fn into_iter(self) -> BatchIntoIter<D> {
        match self {
            Batch::Owned(mut lease) => {
                // Reverse once so by-value draining is `pop` (O(1), keeps
                // the buffer's capacity in place for recycling).
                lease.reverse();
                BatchIntoIter::Owned(lease)
            }
            Batch::Shared(arc) => BatchIntoIter::Shared { arc, next: 0 },
        }
    }
}

/// By-value iterator over a batch (see `Batch::into_iter`).
pub enum BatchIntoIter<D> {
    /// Draining an exclusively owned batch (stored reversed; `pop` yields
    /// original order).
    Owned(Lease<Vec<D>>),
    /// Cloning out of a shared batch.
    Shared {
        /// The shared buffer.
        arc: Arc<Vec<D>>,
        /// Next index to yield.
        next: usize,
    },
}

impl<D: Clone> Iterator for BatchIntoIter<D> {
    type Item = D;

    fn next(&mut self) -> Option<D> {
        match self {
            BatchIntoIter::Owned(lease) => lease.pop(),
            BatchIntoIter::Shared { arc, next } => {
                let item = arc.get(*next).cloned();
                if item.is_some() {
                    *next += 1;
                }
                item
            }
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let remaining = match self {
            BatchIntoIter::Owned(lease) => lease.len(),
            BatchIntoIter::Shared { arc, next } => arc.len() - *next,
        };
        (remaining, Some(remaining))
    }
}

/// A batch of records bearing one timestamp.
#[derive(Clone, Debug)]
pub struct Message<T, D> {
    /// The logical timestamp of every record in the batch.
    pub time: T,
    /// The records.
    pub data: Batch<D>,
    /// The index of the sending worker (diagnostics / tests).
    pub from: usize,
}

/// Idle record buffers retained by a net endpoint's decode pool.
const DECODE_POOL_SLOTS: usize = 32;

/// The data plane's wire format: `time`, sending worker, then the record
/// batch (`u32` count + records), encoded **straight out of the pooled
/// batch slice** — no intermediate copy, whether the payload is an owned
/// lease or a shared broadcast `Arc`.
///
/// Decoding goes **into a pooled lease** when the receiving endpoint
/// supplies its `BufferPool<Vec<D>>` through the reader context
/// ([`Wire::decode_context`] installs one per net endpoint), so the
/// receive side of the cross-process path recycles record buffers exactly
/// like the intra-process path does. Without a context (tests, handshake
/// paths) the batch decodes into a plain un-pooled buffer.
impl<T: Timestamp, D: Data> Wire for Message<T, D> {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.time.encode(buf);
        (self.from as u32).encode(buf);
        let records = self.data.as_slice();
        debug_assert!(records.len() <= u32::MAX as usize);
        (records.len() as u32).encode(buf);
        for record in records {
            record.encode(buf);
        }
    }

    fn decode(reader: &mut WireReader<'_>) -> Result<Self, WireError> {
        let time = T::decode(reader)?;
        let from = reader.u32()? as usize;
        let len = reader.read_len()?;
        let data = match reader.context::<BufferPool<Vec<D>>>() {
            Some(pool) => {
                let mut lease = pool.checkout();
                lease.reserve(len.min(reader.remaining().max(1)));
                for _ in 0..len {
                    lease.push(D::decode(reader)?);
                }
                Batch::Owned(lease)
            }
            None => {
                let mut records = Vec::with_capacity(len.min(reader.remaining().max(1)));
                for _ in 0..len {
                    records.push(D::decode(reader)?);
                }
                Batch::from_vec(records)
            }
        };
        Ok(Message { time, data, from })
    }

    fn decode_context() -> Option<Box<dyn std::any::Any + Send>> {
        Some(Box::new(BufferPool::<Vec<D>>::new(DECODE_POOL_SLOTS)))
    }
}

/// Where an exchanged record should go.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// To the worker `hash % peers`.
    Worker(u64),
    /// To every worker (used for control records, e.g. Flink-style
    /// watermarks in the `-X` configuration).
    All,
}

/// Parallelization contract for a channel.
#[derive(Clone)]
pub enum Pact<D> {
    /// Records stay on the worker that produced them.
    Pipeline,
    /// Records are routed between workers by the given function.
    Exchange(Rc<dyn Fn(&D) -> Route>),
}

impl<D> Pact<D> {
    /// An exchange pact routing by a hash of the record.
    pub fn exchange<F: Fn(&D) -> u64 + 'static>(key: F) -> Self {
        Pact::Exchange(Rc::new(move |d| Route::Worker(key(d))))
    }

    /// An exchange pact with full routing control (per-record broadcast).
    pub fn routed<F: Fn(&D) -> Route + 'static>(route: F) -> Self {
        Pact::Exchange(Rc::new(route))
    }
}

impl<D> std::fmt::Debug for Pact<D> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        match self {
            Pact::Pipeline => write!(f, "Pipeline"),
            Pact::Exchange(_) => write!(f, "Exchange"),
        }
    }
}

/// The shared local mailbox of a channel instance on one worker: both
/// same-worker sends and the drainers of remote receivers push here; the
/// owning operator's input handle pops.
pub type LocalQueue<T, D> = Rc<RefCell<VecDeque<Message<T, D>>>>;

/// The send side of one channel on one worker.
pub struct ChannelSend<T: Timestamp, D: Data> {
    /// Channel identifier (same on every worker).
    pub channel: usize,
    /// The input port this channel feeds.
    pub target: Location,
    /// Parallelization contract.
    pub pact: Pact<D>,
    /// This worker's index.
    pub my_index: usize,
    /// Total workers.
    pub peers: usize,
    /// Staged remote messages, per destination (FIFO within each), released
    /// by `flush_remote`.
    staged: Vec<VecDeque<Message<T, D>>>,
    /// Remote fabric senders, one per peer (`None` at `my_index`): rings
    /// for same-process peers, serializing net endpoints across processes.
    remote: Vec<Option<FabricSender<Message<T, D>>>>,
    /// The local mailbox on this worker (for self-sends).
    local: LocalQueue<T, D>,
    /// Worker-wide flag: set when remote data is staged, so the worker
    /// knows it must broadcast its progress batch (with the corresponding
    /// `+1` produce counts) before releasing the fabric this step.
    staged_flag: Rc<Cell<bool>>,
    /// This worker's fabric counters (ring-full stalls).
    stats: Arc<WorkerStats>,
}

impl<T: Timestamp, D: Data> ChannelSend<T, D> {
    /// Assembles the send side from its parts (done by `Stream::connect_to`).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        channel: usize,
        target: Location,
        pact: Pact<D>,
        my_index: usize,
        peers: usize,
        remote: Vec<Option<FabricSender<Message<T, D>>>>,
        local: LocalQueue<T, D>,
        staged_flag: Rc<Cell<bool>>,
        stats: Arc<WorkerStats>,
    ) -> Self {
        debug_assert_eq!(remote.len(), peers);
        ChannelSend {
            channel,
            target,
            pact,
            my_index,
            peers,
            staged: (0..peers).map(|_| VecDeque::new()).collect(),
            remote,
            local,
            staged_flag,
            stats,
        }
    }

    /// Enqueues a message batch for worker `dest`.
    ///
    /// Local deliveries are immediate (the consume accounting flows through
    /// the same worker's later atomic batches, so ordering is preserved);
    /// remote deliveries are staged until [`flush_remote`].
    ///
    /// [`flush_remote`]: ChannelSend::flush_remote
    pub fn push(&mut self, dest: usize, message: Message<T, D>) {
        if dest == self.my_index {
            self.local.borrow_mut().push_back(message);
        } else {
            self.staged[dest].push_back(message);
            self.staged_flag.set(true);
        }
    }

    /// Releases staged remote messages into the fabric rings. Called by the
    /// worker after its progress batch (containing the `+1` produce counts)
    /// has been broadcast into every peer mailbox.
    ///
    /// Returns `(sent_any, remaining)`: whether any message entered a ring,
    /// and whether any stayed staged behind a full ring (the worker keeps
    /// its remote-pending latch set and retries next flush — holding a
    /// message *longer* is always conservative).
    pub fn flush_remote(&mut self) -> (bool, bool) {
        let mut sent = false;
        let mut remaining = false;
        for dest in 0..self.peers {
            let Some(sender) = self.remote[dest].as_mut() else { continue };
            while let Some(message) = self.staged[dest].pop_front() {
                match sender.send(message) {
                    Ok(()) => sent = true,
                    Err(RingSendError::Full(message)) => {
                        // Preserve FIFO: the rejected message goes back to
                        // the front; retry after the peer drains. Net
                        // endpoints count their own send-queue stalls, so
                        // the ring counter stays ring-only.
                        self.staged[dest].push_front(message);
                        if !sender.is_net() {
                            self.stats.note_ring_full();
                        }
                        remaining = true;
                        break;
                    }
                    Err(RingSendError::Disconnected(_)) => {
                        // The peer worker has shut down; at that point
                        // progress tracking is already complete for the
                        // messages it cared about, so dropping is benign.
                        self.staged[dest].clear();
                        break;
                    }
                }
            }
        }
        (sent, remaining)
    }

    /// True iff remote messages are staged.
    pub fn has_staged(&self) -> bool {
        self.staged.iter().any(|q| !q.is_empty())
    }
}

/// Shared handle to a channel's send side.
pub type ChannelSendHandle<T, D> = Rc<RefCell<ChannelSend<T, D>>>;

/// The list of channels attached to one output port (filled lazily as
/// downstream consumers connect).
pub type TeeHandle<T, D> = Rc<RefCell<Vec<ChannelSendHandle<T, D>>>>;

/// Builds a drainer closure that moves messages from a remote fabric
/// endpoint (ring or net) into the channel's local mailbox; returns
/// whether any message moved.
pub fn drainer<T: Timestamp, D: Data>(
    mut receiver: FabricReceiver<Message<T, D>>,
    queue: LocalQueue<T, D>,
) -> Box<dyn FnMut() -> bool> {
    Box::new(move || {
        let mut any = false;
        loop {
            match receiver.try_recv() {
                Ok(message) => {
                    queue.borrow_mut().push_back(message);
                    any = true;
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        any
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::worker::ring;

    fn msg(t: u64, data: Vec<u32>) -> Message<u64, u32> {
        Message { time: t, data: Batch::from_vec(data), from: 0 }
    }

    fn stats() -> Arc<WorkerStats> {
        Arc::new(WorkerStats::default())
    }

    #[test]
    fn local_push_is_immediate() {
        let local: LocalQueue<u64, u32> = Rc::new(RefCell::new(VecDeque::new()));
        let mut send = ChannelSend::new(
            0,
            Location::target(1, 0),
            Pact::Pipeline,
            0,
            1,
            vec![None],
            local.clone(),
            Rc::new(Cell::new(false)),
            stats(),
        );
        send.push(0, msg(3, vec![1, 2]));
        assert_eq!(local.borrow().len(), 1);
        assert!(!send.has_staged());
    }

    #[test]
    fn remote_push_staged_until_flush() {
        let (tx, mut rx) = ring::channel(8);
        let tx = FabricSender::Ring(tx);
        let local: LocalQueue<u64, u32> = Rc::new(RefCell::new(VecDeque::new()));
        let flag = Rc::new(Cell::new(false));
        let mut send = ChannelSend::new(
            0,
            Location::target(1, 0),
            Pact::Pipeline,
            0,
            2,
            vec![None, Some(tx)],
            local,
            flag.clone(),
            stats(),
        );
        send.push(1, msg(3, vec![7]));
        assert!(send.has_staged());
        assert!(flag.get(), "staged flag must be raised for remote pushes");
        assert!(rx.try_recv().is_err());
        let (sent, remaining) = send.flush_remote();
        assert!(sent && !remaining);
        assert_eq!(&rx.try_recv().unwrap().data[..], &[7]);
        assert!(!send.has_staged());
    }

    #[test]
    fn full_ring_keeps_messages_staged_in_order() {
        let (tx, mut rx) = ring::channel(2);
        let tx = FabricSender::Ring(tx);
        let local: LocalQueue<u64, u32> = Rc::new(RefCell::new(VecDeque::new()));
        let counters = stats();
        let mut send = ChannelSend::new(
            0,
            Location::target(1, 0),
            Pact::Pipeline,
            0,
            2,
            vec![None, Some(tx)],
            local,
            Rc::new(Cell::new(false)),
            counters.clone(),
        );
        for t in 0..4u64 {
            send.push(1, msg(t, vec![t as u32]));
        }
        // Ring holds 2: the rest stays staged, in order.
        let (sent, remaining) = send.flush_remote();
        assert!(sent && remaining);
        assert!(send.has_staged());
        assert_eq!(rx.try_recv().unwrap().time, 0);
        assert_eq!(rx.try_recv().unwrap().time, 1);
        // Retry delivers the remainder, still in order.
        let (sent, remaining) = send.flush_remote();
        assert!(sent && !remaining);
        assert_eq!(rx.try_recv().unwrap().time, 2);
        assert_eq!(rx.try_recv().unwrap().time, 3);
        assert!(!send.has_staged());
    }

    #[test]
    fn disconnected_peer_discards_staged() {
        let (tx, rx) = ring::channel::<Message<u64, u32>>(4);
        let tx = FabricSender::Ring(tx);
        drop(rx);
        let local: LocalQueue<u64, u32> = Rc::new(RefCell::new(VecDeque::new()));
        let mut send = ChannelSend::new(
            0,
            Location::target(1, 0),
            Pact::Pipeline,
            0,
            2,
            vec![None, Some(tx)],
            local,
            Rc::new(Cell::new(false)),
            stats(),
        );
        send.push(1, msg(1, vec![9]));
        let (sent, remaining) = send.flush_remote();
        assert!(!sent && !remaining);
        assert!(!send.has_staged());
    }

    #[test]
    fn drainer_moves_messages() {
        let (mut tx, rx) = ring::channel(8);
        let queue: LocalQueue<u64, u32> = Rc::new(RefCell::new(VecDeque::new()));
        let mut drain = drainer(FabricReceiver::Ring(rx), queue.clone());
        assert!(!drain());
        tx.send(msg(1, vec![1])).unwrap();
        tx.send(msg(2, vec![2])).unwrap();
        assert!(drain());
        assert_eq!(queue.borrow().len(), 2);
        // Disconnect is handled quietly.
        drop(tx);
        assert!(!drain());
    }

    #[test]
    fn pact_exchange_routes() {
        let pact = Pact::exchange(|d: &u64| *d);
        if let Pact::Exchange(route) = &pact {
            assert_eq!(route(&5), Route::Worker(5));
        } else {
            panic!("not exchange");
        }
        let pact = Pact::<u64>::routed(|_| Route::All);
        if let Pact::Exchange(route) = &pact {
            assert_eq!(route(&5), Route::All);
        } else {
            panic!("not exchange");
        }
    }

    #[test]
    fn owned_batch_drains_by_value_in_order() {
        let batch = Batch::from_vec(vec![1u32, 2, 3]);
        assert_eq!(batch.len(), 3);
        let collected: Vec<u32> = batch.into_iter().collect();
        assert_eq!(collected, vec![1, 2, 3]);
    }

    #[test]
    fn shared_batch_clones_out_in_order() {
        let arc = Arc::new(vec![4u32, 5, 6]);
        let a = Batch::Shared(arc.clone());
        let b = Batch::Shared(arc);
        assert!(a.is_shared());
        assert_eq!(a.into_iter().collect::<Vec<_>>(), vec![4, 5, 6]);
        // The other clone is unaffected.
        assert_eq!(&b[..], &[4, 5, 6]);
    }

    #[test]
    fn owned_batch_returns_buffer_to_pool_after_drain() {
        let pool = crate::buffer::BufferPool::<Vec<u32>>::new(2);
        let mut lease = pool.checkout();
        lease.extend([7u32, 8, 9]);
        let batch = Batch::Owned(lease);
        let collected: Vec<u32> = batch.into_iter().collect();
        assert_eq!(collected, vec![7, 8, 9]);
        // The drained buffer went back to the pool.
        assert_eq!(pool.stats().reused + pool.stats().overflowed, 0);
        let recycled = pool.checkout();
        assert!(recycled.capacity() >= 3);
        assert_eq!(pool.stats().reused, 1);
    }
}
