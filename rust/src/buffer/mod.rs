//! Recycling buffer pools: the allocation story of the zero-allocation
//! data plane.
//!
//! The steady-state data path moves one message batch per `SEND_BATCH`
//! records, and before this module existed every one of those batches was
//! a fresh `Vec` (and, for progress batches, a fresh `Arc`) handed to the
//! allocator and dropped on the far side of a channel. Two primitives
//! remove that churn:
//!
//! * [`BufferPool`] / [`Lease`] — a lock-free, cross-thread recycler for
//!   exclusively owned buffers. A [`Lease`] behaves like the `V` it wraps
//!   (`Deref`/`DerefMut`) and **returns its buffer to the pool on drop**,
//!   from whichever thread drops it — the consumer of a message batch
//!   recycles the producer's capacity without either side taking a lock
//!   (the free list is a fixed array of atomically claimed slots).
//!
//! * [`SharedPool`] — a producer-local recycler for *shared* (`Arc`-backed)
//!   batches, used where one buffer fans out to many consumers (broadcast
//!   data batches, progress batches). Consumers just drop their `Arc`
//!   clones; the producer reclaims a batch — control block **and**
//!   capacity, in one piece — once every clone is gone, by scanning its
//!   in-flight window for a uniquely referenced entry.
//!
//! Neither pool blocks, neither pool allocates on the reuse path, and both
//! degrade gracefully: a full free list drops the buffer, an empty one
//! allocates — correctness never depends on recycling succeeding.
//!
//! Leases are also the unit of **whole-batch forwarding**: because a
//! [`Lease`] carries its home pool with it, a pipeline operator can hand
//! an arriving batch to its own output as-is (`Session::give_batch`) and
//! let it travel any number of hops — whichever worker finally drains it
//! returns the capacity to the pool that minted it, with every
//! intermediate operator paying zero per-record and zero per-buffer cost.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;

/// A buffer that can be wiped for reuse while keeping its capacity.
pub trait Recycle {
    /// Resets the buffer to its logically empty state.
    fn recycle(&mut self);
}

impl<T> Recycle for Vec<T> {
    fn recycle(&mut self) {
        self.clear();
    }
}

impl<T> Recycle for VecDeque<T> {
    fn recycle(&mut self) {
        self.clear();
    }
}

// ---------------------------------------------------------------------------
// BufferPool: exclusively owned buffers, returned on last drop.
// ---------------------------------------------------------------------------

/// Slot states of the lock-free free list.
const SLOT_EMPTY: u8 = 0;
const SLOT_FULL: u8 = 1;
const SLOT_BUSY: u8 = 2;

/// The shared free list: a fixed array of slots, each claimed by a CAS to
/// `SLOT_BUSY` before its value cell is touched, so every cell access is
/// exclusive. Threads never wait on each other — a contended slot is simply
/// skipped.
struct Shelf<V> {
    states: Box<[AtomicU8]>,
    values: Box<[UnsafeCell<Option<V>>]>,
    /// Buffers handed out from the free list (vs freshly allocated).
    reused: AtomicU64,
    /// Buffers freshly allocated because the free list was empty.
    allocated: AtomicU64,
    /// Buffers dropped because the free list was full.
    overflowed: AtomicU64,
}

// SAFETY: a slot's value cell is only accessed by the thread that CASed its
// state to SLOT_BUSY, and the Acquire/Release pairs on the state transfer
// the value between threads.
unsafe impl<V: Send> Send for Shelf<V> {}
unsafe impl<V: Send> Sync for Shelf<V> {}

impl<V> Shelf<V> {
    fn new(slots: usize) -> Self {
        let slots = slots.max(1);
        Shelf {
            states: (0..slots).map(|_| AtomicU8::new(SLOT_EMPTY)).collect(),
            values: (0..slots).map(|_| UnsafeCell::new(None)).collect(),
            reused: AtomicU64::new(0),
            allocated: AtomicU64::new(0),
            overflowed: AtomicU64::new(0),
        }
    }

    /// Stores `v` in a free slot; drops it if every slot is occupied.
    fn put(&self, v: V) {
        for (state, cell) in self.states.iter().zip(self.values.iter()) {
            if state
                .compare_exchange(SLOT_EMPTY, SLOT_BUSY, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                // SAFETY: the CAS above grants exclusive access to the cell.
                unsafe { *cell.get() = Some(v) };
                state.store(SLOT_FULL, Ordering::Release);
                return;
            }
        }
        self.overflowed.fetch_add(1, Ordering::Relaxed);
        // `v` dropped: the pool is full, freeing is the correct fallback.
    }

    /// Takes a recycled buffer, if any slot holds one.
    fn take(&self) -> Option<V> {
        for (state, cell) in self.states.iter().zip(self.values.iter()) {
            if state
                .compare_exchange(SLOT_FULL, SLOT_BUSY, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                // SAFETY: the CAS above grants exclusive access to the cell.
                let v = unsafe { (*cell.get()).take() };
                state.store(SLOT_EMPTY, Ordering::Release);
                debug_assert!(v.is_some(), "FULL slot held no value");
                return v;
            }
        }
        None
    }
}

/// Counters describing how a pool has been used (telemetry / tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Checkouts served from the free list.
    pub reused: u64,
    /// Checkouts that had to allocate.
    pub allocated: u64,
    /// Returns dropped because the free list was full.
    pub overflowed: u64,
}

/// A lock-free recycling pool of exclusively owned buffers.
///
/// Cloning the pool clones a handle; all clones share one free list. The
/// pool is `Send + Sync` (for `V: Send`) so leases can migrate across
/// worker threads and still return home.
pub struct BufferPool<V: Recycle + Default> {
    shelf: Arc<Shelf<V>>,
}

impl<V: Recycle + Default> Clone for BufferPool<V> {
    fn clone(&self) -> Self {
        BufferPool { shelf: self.shelf.clone() }
    }
}

impl<V: Recycle + Default> BufferPool<V> {
    /// A pool retaining at most `slots` idle buffers.
    pub fn new(slots: usize) -> Self {
        BufferPool { shelf: Arc::new(Shelf::new(slots)) }
    }

    /// Checks out a buffer: recycled if available, freshly allocated
    /// otherwise. The buffer returns to this pool when the lease drops.
    pub fn checkout(&self) -> Lease<V> {
        let value = match self.shelf.take() {
            Some(v) => {
                self.shelf.reused.fetch_add(1, Ordering::Relaxed);
                v
            }
            None => {
                self.shelf.allocated.fetch_add(1, Ordering::Relaxed);
                V::default()
            }
        };
        Lease { value, shelf: Some(self.shelf.clone()) }
    }

    /// Usage counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            reused: self.shelf.reused.load(Ordering::Relaxed),
            allocated: self.shelf.allocated.load(Ordering::Relaxed),
            overflowed: self.shelf.overflowed.load(Ordering::Relaxed),
        }
    }
}

/// An exclusively owned, pooled buffer: dereferences to `V` and returns
/// the (recycled) buffer to its pool on drop — from any thread.
pub struct Lease<V: Recycle + Default> {
    value: V,
    /// `None` for un-pooled leases (the buffer is simply dropped).
    shelf: Option<Arc<Shelf<V>>>,
}

impl<V: Recycle + Default> Lease<V> {
    /// Wraps a plain value in a lease that does NOT return to any pool —
    /// useful where a one-off buffer enters a pooled code path.
    pub fn unpooled(value: V) -> Self {
        Lease { value, shelf: None }
    }

    /// Detaches the buffer from the pool, consuming the lease.
    pub fn into_inner(mut self) -> V {
        self.shelf = None;
        std::mem::take(&mut self.value)
    }
}

impl<V: Recycle + Default> Deref for Lease<V> {
    type Target = V;
    #[inline]
    fn deref(&self) -> &V {
        &self.value
    }
}

impl<V: Recycle + Default> DerefMut for Lease<V> {
    #[inline]
    fn deref_mut(&mut self) -> &mut V {
        &mut self.value
    }
}

impl<V: Recycle + Default> Drop for Lease<V> {
    fn drop(&mut self) {
        if let Some(shelf) = self.shelf.take() {
            let mut value = std::mem::take(&mut self.value);
            value.recycle();
            shelf.put(value);
        }
    }
}

impl<V: Recycle + Default + std::fmt::Debug> std::fmt::Debug for Lease<V> {
    fn fmt(&self, f: &mut std::fmt::Formatter) -> std::fmt::Result {
        f.debug_tuple("Lease").field(&self.value).finish()
    }
}

// ---------------------------------------------------------------------------
// SharedPool: Arc-backed batches fanned out to many consumers.
// ---------------------------------------------------------------------------

/// A producer-local recycler of shared (`Arc`-backed) batches.
///
/// [`SharedPool::checkout`] yields a **uniquely referenced** `Arc<V>` the
/// producer can fill through [`Arc::get_mut`]; [`SharedPool::track`]
/// registers the sealed batch in a bounded in-flight window. Once every
/// consumer clone has dropped, a later checkout finds the tracked entry
/// uniquely referenced again and reuses it whole — the `Arc` control block
/// is recycled along with the buffer, so a steady-state
/// checkout/track/drop cycle performs no allocation at all.
///
/// Not `Sync`/shared: the pool lives with the one producer that fills the
/// batches (consumers interact only through `Arc` reference counts).
pub struct SharedPool<V: Recycle + Default> {
    in_flight: VecDeque<Arc<V>>,
    limit: usize,
    reused: u64,
    allocated: u64,
}

impl<V: Recycle + Default> SharedPool<V> {
    /// A pool tracking at most `limit` in-flight batches.
    pub fn new(limit: usize) -> Self {
        SharedPool {
            in_flight: VecDeque::with_capacity(limit.max(1)),
            limit: limit.max(1),
            reused: 0,
            allocated: 0,
        }
    }

    /// A uniquely referenced batch, recycled from the in-flight window when
    /// some tracked batch has been dropped by every consumer.
    pub fn checkout(&mut self) -> Arc<V> {
        // Oldest first: in-flight batches retire roughly in FIFO order.
        for i in 0..self.in_flight.len() {
            if Arc::strong_count(&self.in_flight[i]) == 1 {
                let mut arc = self.in_flight.remove(i).expect("index in bounds");
                Arc::get_mut(&mut arc).expect("uniquely referenced").recycle();
                self.reused += 1;
                return arc;
            }
        }
        self.allocated += 1;
        Arc::new(V::default())
    }

    /// Registers a sealed batch for future reclamation. When the window is
    /// full the oldest entry is forgotten (it frees normally on last drop).
    pub fn track(&mut self, batch: &Arc<V>) {
        if self.in_flight.len() == self.limit {
            self.in_flight.pop_front();
        }
        self.in_flight.push_back(batch.clone());
    }

    /// Usage counters (reuse vs allocation).
    pub fn stats(&self) -> PoolStats {
        PoolStats { reused: self.reused, allocated: self.allocated, overflowed: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::property;

    #[test]
    fn lease_returns_capacity_to_pool() {
        let pool = BufferPool::<Vec<u64>>::new(4);
        {
            let mut lease = pool.checkout();
            lease.extend(0..100u64);
            assert_eq!(lease.len(), 100);
        }
        // The returned buffer comes back cleared, capacity intact.
        let lease = pool.checkout();
        assert!(lease.is_empty());
        assert!(lease.capacity() >= 100, "capacity must be recycled");
        assert_eq!(pool.stats().reused, 1);
    }

    #[test]
    fn unpooled_lease_just_drops() {
        let pool = BufferPool::<Vec<u64>>::new(2);
        drop(Lease::unpooled(vec![1u64, 2, 3]));
        assert_eq!(pool.stats().reused, 0);
        let _ = pool.checkout();
        assert_eq!(pool.stats().allocated, 1);
    }

    #[test]
    fn into_inner_detaches_from_pool() {
        let pool = BufferPool::<Vec<u64>>::new(2);
        let mut lease = pool.checkout();
        lease.push(9);
        let v = lease.into_inner();
        assert_eq!(v, vec![9]);
        // Nothing returned: next checkout allocates.
        let _ = pool.checkout();
        assert_eq!(pool.stats().reused, 0);
    }

    #[test]
    fn full_shelf_drops_excess_returns() {
        let pool = BufferPool::<Vec<u64>>::new(1);
        let a = pool.checkout();
        let b = pool.checkout();
        drop(a);
        drop(b); // shelf already holds `a`'s buffer
        assert_eq!(pool.stats().overflowed, 1);
    }

    #[test]
    fn cross_thread_return() {
        let pool = BufferPool::<Vec<u64>>::new(4);
        let mut lease = pool.checkout();
        lease.extend(0..512u64);
        let handle = std::thread::spawn(move || drop(lease));
        handle.join().unwrap();
        let lease = pool.checkout();
        assert!(lease.capacity() >= 512);
        assert_eq!(pool.stats().reused, 1);
    }

    /// Pooled leases never alias: however checkouts, fills, and returns
    /// interleave, the set of live leases always holds pairwise-distinct
    /// buffers, and a checked-out buffer is always logically empty.
    #[test]
    fn leases_never_alias_live_batches() {
        property("leases_never_alias_live_batches", 20, |_case, rng| {
            let pool = BufferPool::<Vec<u64>>::new(4);
            let mut live: Vec<(u64, Lease<Vec<u64>>)> = Vec::new();
            let mut next_tag = 0u64;
            for _ in 0..200 {
                if live.is_empty() || rng.chance(0.5) {
                    let mut lease = pool.checkout();
                    assert!(lease.is_empty(), "checked-out buffer must be empty");
                    // Stamp the buffer with a unique tag.
                    lease.push(next_tag);
                    live.push((next_tag, lease));
                    next_tag += 1;
                } else {
                    let i = rng.below(live.len() as u64) as usize;
                    let (tag, lease) = live.swap_remove(i);
                    assert_eq!(lease[0], tag, "lease content clobbered while live");
                    drop(lease);
                }
                // Every live lease still holds exactly its own stamp.
                for (tag, lease) in &live {
                    assert_eq!(lease.len(), 1, "live lease aliased and refilled");
                    assert_eq!(lease[0], *tag, "live leases alias one buffer");
                }
            }
        });
    }

    /// Reuse preserves message contents and ordering: batches round-tripped
    /// through pool + channel arrive exactly as sent, even as buffers
    /// recycle under randomized consumer timing.
    #[test]
    fn pool_reuse_preserves_contents_and_order() {
        property("pool_reuse_preserves_contents_and_order", 10, |_case, rng| {
            let pool = BufferPool::<Vec<u64>>::new(4);
            let mut in_transit: VecDeque<(u64, Lease<Vec<u64>>)> = VecDeque::new();
            let mut next_sent = 0u64;
            let mut next_recv = 0u64;
            for _ in 0..300 {
                if rng.chance(0.6) {
                    // Send: fill a pooled batch with a recognizable run.
                    let mut lease = pool.checkout();
                    let len = rng.range(1, 64);
                    lease.extend((0..len).map(|i| next_sent * 1000 + i));
                    in_transit.push_back((next_sent, lease));
                    next_sent += 1;
                } else if let Some((seq, lease)) = in_transit.pop_front() {
                    // Receive: FIFO order, contents intact.
                    assert_eq!(seq, next_recv, "batch order violated");
                    for (i, &v) in lease.iter().enumerate() {
                        assert_eq!(v, seq * 1000 + i as u64, "batch contents clobbered");
                    }
                    next_recv += 1;
                    drop(lease); // recycle
                }
            }
            assert!(pool.stats().reused > 0, "reuse must actually occur");
        });
    }

    #[test]
    fn shared_pool_recycles_unique_batches() {
        let mut pool = SharedPool::<Vec<u64>>::new(4);
        let mut arc = pool.checkout();
        Arc::get_mut(&mut arc).unwrap().extend(0..64u64);
        pool.track(&arc);
        let consumer = arc.clone();
        drop(arc);
        // Still held by `consumer`: checkout must not steal it.
        let other = pool.checkout();
        assert!(other.is_empty());
        assert_eq!(pool.stats().allocated, 2);
        drop(other);
        drop(consumer);
        // Now uniquely held by the pool: recycled, capacity intact.
        let recycled = pool.checkout();
        assert!(recycled.is_empty());
        assert!(recycled.capacity() >= 64);
        assert_eq!(pool.stats().reused, 1);
    }

    #[test]
    fn shared_pool_window_is_bounded() {
        let mut pool = SharedPool::<Vec<u64>>::new(2);
        for _ in 0..10 {
            let arc = pool.checkout();
            pool.track(&arc);
            // All clones dropped immediately: every later checkout reuses.
        }
        assert!(pool.stats().reused >= 8);
        assert!(pool.in_flight.len() <= 2);
    }
}
