//! Flink-style watermarks, re-implemented on the token substrate (§7).
//!
//! Watermarks are *in-stream control records*: every operator must be
//! scheduled to observe, merge (min across upstream instances), and
//! re-emit them — even when it has no data — which is exactly the cost the
//! paper's Figure 8 measures. Under the hood each watermark operator holds
//! exactly one timestamp token per output and downgrades it as its output
//! watermark advances (§4), so the engine's progress tracking stays sound
//! without the operator ever reading a frontier.
//!
//! Two wirings, as in §7.3:
//! * [`WmWiring::Exchanged`] (watermarks-X): data routed by key, marks
//!   broadcast to every worker at every stage;
//! * [`WmWiring::Pipelined`] (watermarks-P): operators form worker-local
//!   pipelines (the paper's "unrealistic" best case for watermarks).

use crate::dataflow::channels::{Data, Pact, Route};
use crate::dataflow::input::InputSession;
use crate::dataflow::operator::OperatorExt;
use crate::dataflow::stream::Stream;
use crate::dataflow::token::TimestampToken;
use crate::worker::Worker;
use std::cell::Cell;
use std::rc::Rc;

/// The watermark value that signals a closed stream.
pub const WM_CLOSED: u64 = u64::MAX;

/// A record on a watermark-coordinated stream: event-time data or a
/// watermark from one upstream operator instance.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WmRecord<D> {
    /// A data record with its event time (nanoseconds).
    Data(u64, D),
    /// "Upstream instance `from` will send no data with event time < `wm`."
    Mark {
        /// Sending worker's index.
        from: usize,
        /// The watermark.
        wm: u64,
    },
}

/// Wire format: tag byte (0 = data, 1 = mark), then the variant fields —
/// watermark streams exchange and broadcast records, so they must cross
/// process boundaries like any other channel payload.
impl<D: crate::net::Wire> crate::net::Wire for WmRecord<D> {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            WmRecord::Data(te, d) => {
                buf.push(0);
                te.encode(buf);
                d.encode(buf);
            }
            WmRecord::Mark { from, wm } => {
                buf.push(1);
                from.encode(buf);
                wm.encode(buf);
            }
        }
    }
    fn decode(
        reader: &mut crate::net::WireReader<'_>,
    ) -> Result<Self, crate::net::WireError> {
        match reader.u8()? {
            0 => Ok(WmRecord::Data(u64::decode(reader)?, D::decode(reader)?)),
            1 => Ok(WmRecord::Mark { from: usize::decode(reader)?, wm: u64::decode(reader)? }),
            _ => Err(crate::net::WireError::Malformed("wm record tag")),
        }
    }
}

/// Channel wiring for watermark operators (§7.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WmWiring {
    /// Cross-worker exchange at every stage; marks broadcast (watermarks-X).
    Exchanged,
    /// Worker-local pipelines; marks stay local (watermarks-P).
    Pipelined,
}

/// Operator logic under watermark coordination.
pub trait WmLogic<D, D2>: 'static {
    /// Called per data record; emissions are `(event_time, record)` pairs.
    fn on_data(&mut self, event_time: u64, record: D, out: &mut Vec<(u64, D2)>);
    /// Called when the operator's *input* watermark advances.
    fn on_watermark(&mut self, wm: u64, out: &mut Vec<(u64, D2)>);
}

/// A pass-through (no-op) watermark operator: the idle-pipeline workload of
/// Figure 8.
pub struct WmNoop;
impl<D> WmLogic<D, D> for WmNoop {
    fn on_data(&mut self, event_time: u64, record: D, out: &mut Vec<(u64, D)>) {
        out.push((event_time, record));
    }
    fn on_watermark(&mut self, _wm: u64, _out: &mut Vec<(u64, D)>) {}
}

/// Tracks the minimum watermark across the expected upstream instances.
pub struct WmMerger {
    senders: Vec<u64>,
    merged: u64,
}

impl WmMerger {
    /// A merger expecting marks from `expected` upstream instances (slots
    /// are worker indices for exchanged wirings).
    pub fn new(expected: usize) -> Self {
        WmMerger { senders: vec![0; expected.max(1)], merged: 0 }
    }

    /// Folds in a mark; returns the new merged watermark if it advanced.
    pub fn observe(&mut self, from: usize, wm: u64) -> Option<u64> {
        let slot = from % self.senders.len();
        if wm > self.senders[slot] {
            self.senders[slot] = wm;
        }
        let min = *self.senders.iter().min().expect("nonempty");
        if min > self.merged {
            self.merged = min;
            Some(min)
        } else {
            None
        }
    }

    /// The current merged watermark.
    pub fn current(&self) -> u64 {
        self.merged
    }
}

/// Watermark-coordinated operators on streams of [`WmRecord`]s.
pub trait WatermarkExt<D: Data> {
    /// A unary watermark operator: routes data by `key` (under
    /// [`WmWiring::Exchanged`]), merges upstream marks, invokes `logic`,
    /// forwards its output watermark downstream, and downgrades its held
    /// token accordingly.
    fn wm_unary<D2: Data, K, L>(
        &self,
        wiring: WmWiring,
        name: &str,
        key: K,
        logic: L,
    ) -> Stream<u64, WmRecord<D2>>
    where
        K: Fn(&D) -> u64 + 'static,
        L: WmLogic<D, D2>;

    /// A chain of `n` no-op watermark operators (Figure 8's workload).
    fn wm_noop_chain(&self, wiring: WmWiring, n: usize) -> Stream<u64, WmRecord<D>>;

    /// A terminal watermark observer: `on_advance` fires with each merged
    /// watermark advance; the returned probe reports the sink watermark.
    fn wm_probe<F: FnMut(u64) + 'static>(&self, on_advance: F) -> WmProbeHandle;
}

impl<D: Data> WatermarkExt<D> for Stream<u64, WmRecord<D>> {
    fn wm_unary<D2: Data, K, L>(
        &self,
        wiring: WmWiring,
        name: &str,
        key: K,
        mut logic: L,
    ) -> Stream<u64, WmRecord<D2>>
    where
        K: Fn(&D) -> u64 + 'static,
        L: WmLogic<D, D2>,
    {
        let peers = self.scope().peers();
        let pact = match wiring {
            WmWiring::Exchanged => Pact::routed(move |rec: &WmRecord<D>| match rec {
                WmRecord::Data(_, d) => Route::Worker(key(d)),
                WmRecord::Mark { .. } => Route::All,
            }),
            WmWiring::Pipelined => Pact::Pipeline,
        };
        let expected = match wiring {
            WmWiring::Exchanged => peers,
            WmWiring::Pipelined => 1,
        };
        self.unary(pact, name, move |tok, info| {
            // The operator's single held token, tracking its output
            // watermark; dropped once the stream closes.
            let mut held: Option<TimestampToken<u64>> = Some(tok);
            let mut merger = WmMerger::new(expected);
            let mut scratch: Vec<(u64, D2)> = Vec::new();
            let mut outgoing: Vec<WmRecord<D2>> = Vec::new();
            let my_index = info.worker;
            move |input: &mut _, output: &mut _| {
                let mut advanced: Option<u64> = None;
                while let Some((_token, data)) = input.next() {
                    // NB: the engine's token ref is ignored — watermark
                    // operators coordinate through marks alone.
                    for rec in data {
                        match rec {
                            WmRecord::Data(te, d) => {
                                logic.on_data(te, d, &mut scratch);
                                outgoing
                                    .extend(scratch.drain(..).map(|(t, d)| WmRecord::Data(t, d)));
                            }
                            WmRecord::Mark { from, wm } => {
                                if let Some(new_wm) = merger.observe(from, wm) {
                                    logic.on_watermark(new_wm, &mut scratch);
                                    outgoing.extend(
                                        scratch.drain(..).map(|(t, d)| WmRecord::Data(t, d)),
                                    );
                                    // One mark per advance: downstream
                                    // operators pay per watermark, as in
                                    // Flink (Figure 8's cost model).
                                    outgoing.push(WmRecord::Mark { from: my_index, wm: new_wm });
                                    advanced = Some(new_wm);
                                }
                            }
                        }
                    }
                }
                // Emit everything under the currently held token, then
                // downgrade (or release) it to the new output watermark.
                if let Some(token) = held.as_mut() {
                    if !outgoing.is_empty() {
                        let mut session = output.session(&*token);
                        for rec in outgoing.drain(..) {
                            session.give(rec);
                        }
                    }
                    match advanced {
                        Some(WM_CLOSED) => {
                            held = None; // closed: release the token
                        }
                        Some(wm) => token.downgrade(&wm),
                        None => {}
                    }
                }
            }
        })
    }

    fn wm_noop_chain(&self, wiring: WmWiring, n: usize) -> Stream<u64, WmRecord<D>> {
        let mut stream = self.clone();
        for i in 0..n {
            stream = stream.wm_unary(wiring, &format!("wm_noop_{i}"), |_d| 0, WmNoop);
        }
        stream
    }

    fn wm_probe<F: FnMut(u64) + 'static>(&self, mut on_advance: F) -> WmProbeHandle {
        let wm = Rc::new(Cell::new(0u64));
        let wm2 = wm.clone();
        self.sink(Pact::Pipeline, "wm_probe", move |_info| {
            let mut merger = WmMerger::new(1);
            move |input: &mut _| {
                while let Some((_token, data)) = input.next() {
                    for rec in data {
                        if let WmRecord::Mark { from, wm } = rec {
                            if let Some(new_wm) = merger.observe(from, wm) {
                                wm2.set(new_wm);
                                on_advance(new_wm);
                            }
                        }
                    }
                }
            }
        });
        WmProbeHandle { wm }
    }
}

/// Observed sink watermark (the watermark analogue of a frontier probe).
#[derive(Clone)]
pub struct WmProbeHandle {
    wm: Rc<Cell<u64>>,
}

impl WmProbeHandle {
    /// The sink's merged watermark.
    pub fn watermark(&self) -> u64 {
        self.wm.get()
    }

    /// True iff the stream has closed.
    pub fn done(&self) -> bool {
        self.wm.get() == WM_CLOSED
    }
}

/// An input adapter for watermark-coordinated dataflows: wraps an
/// [`InputSession`], interleaving watermarks with data and keeping the
/// engine epoch in lockstep with the source watermark.
pub struct WmInput<D: Data> {
    session: InputSession<u64, WmRecord<D>>,
    index: usize,
    wm: u64,
}

impl<D: Data> WmInput<D> {
    /// Creates the watermark input for `worker`.
    pub fn new(worker: &mut Worker<u64>) -> (Self, Stream<u64, WmRecord<D>>) {
        let index = worker.index();
        let (session, stream) = worker.new_input::<WmRecord<D>>();
        (WmInput { session, index, wm: 0 }, stream)
    }

    /// Sends a data record with event time `te ≥ watermark()`.
    pub fn send(&mut self, te: u64, record: D) {
        debug_assert!(te >= self.wm, "event time {te} below watermark {}", self.wm);
        self.session.send(WmRecord::Data(te, record));
    }

    /// The current source watermark.
    pub fn watermark(&self) -> u64 {
        self.wm
    }

    /// Advances the source watermark, emitting a mark in-stream and moving
    /// the engine epoch along with it.
    pub fn advance_watermark(&mut self, wm: u64) {
        assert!(wm >= self.wm, "watermarks must advance");
        if wm > self.wm {
            self.wm = wm;
            self.session.send(WmRecord::Mark { from: self.index, wm });
            if wm != WM_CLOSED {
                self.session.advance_to(wm);
            }
        }
    }

    /// Closes the input: emits the closing mark and drops the session token.
    pub fn close(&mut self) {
        if self.wm != WM_CLOSED {
            self.wm = WM_CLOSED;
            self.session.send(WmRecord::Mark { from: self.index, wm: WM_CLOSED });
            self.session.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::worker::execute::{execute, execute_single};

    /// Rolling count under watermark coordination (the §7.2 workload).
    struct WmCount {
        counts: std::collections::HashMap<u64, u64>,
    }
    impl WmLogic<u64, (u64, u64)> for WmCount {
        fn on_data(&mut self, te: u64, word: u64, out: &mut Vec<(u64, (u64, u64))>) {
            let c = self.counts.entry(word).or_insert(0);
            *c += 1;
            out.push((te, (word, *c)));
        }
        fn on_watermark(&mut self, _wm: u64, _out: &mut Vec<(u64, (u64, u64))>) {}
    }

    #[test]
    fn merger_takes_min_across_senders() {
        let mut m = WmMerger::new(2);
        assert_eq!(m.observe(0, 5), None); // sender 1 still at 0
        assert_eq!(m.observe(1, 3), Some(3));
        assert_eq!(m.observe(1, 10), Some(5));
        assert_eq!(m.current(), 5);
        // Stale marks are ignored.
        assert_eq!(m.observe(1, 4), None);
    }

    #[test]
    fn single_worker_wordcount_with_watermarks() {
        let got = execute_single::<u64, _, _>(|worker| {
            let (mut input, stream) = WmInput::<u64>::new(worker);
            let out = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
            let out2 = out.clone();
            let counted = stream.wm_unary(
                WmWiring::Exchanged,
                "wm_wordcount",
                |w: &u64| *w,
                WmCount { counts: Default::default() },
            );
            let probe = counted.wm_probe(move |wm| out2.borrow_mut().push(wm));
            input.send(10, 7);
            input.send(11, 7);
            input.advance_watermark(20);
            input.close();
            worker.step_while(|| !probe.done());
            let marks = out.borrow().clone();
            marks
        });
        assert_eq!(got, vec![20, WM_CLOSED]);
    }

    #[test]
    fn chain_propagates_watermarks_across_workers() {
        let results = execute::<u64, _, _>(
            Config { workers: 2, pin_workers: false, ..Default::default() },
            |worker| {
                let (mut input, stream) = WmInput::<u64>::new(worker);
                let probe = stream
                    .wm_noop_chain(WmWiring::Exchanged, 4)
                    .wm_probe(|_| {});
                input.send(5, worker.index() as u64);
                input.advance_watermark(100);
                input.close();
                worker.step_while(|| !probe.done());
                probe.watermark()
            },
        );
        assert_eq!(results, vec![WM_CLOSED, WM_CLOSED]);
    }

    #[test]
    fn pipelined_wiring_stays_local() {
        // With pipelined wiring each worker's chain closes independently.
        let results = execute::<u64, _, _>(
            Config { workers: 2, pin_workers: false, ..Default::default() },
            |worker| {
                let (mut input, stream) = WmInput::<u64>::new(worker);
                let probe = stream
                    .wm_noop_chain(WmWiring::Pipelined, 8)
                    .wm_probe(|_| {});
                input.send(1, 42);
                input.advance_watermark(50);
                input.close();
                worker.step_while(|| !probe.done());
                probe.watermark()
            },
        );
        assert_eq!(results, vec![WM_CLOSED, WM_CLOSED]);
    }
}
