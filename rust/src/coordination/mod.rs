//! The three coordination mechanisms the paper evaluates (§7), all built on
//! the same substrate — exactly the methodology of §7: "In order to compare
//! with Flink-style watermarks without the confounding factor of running on
//! a different platform ... we re-implemented Flink's watermarks technique
//! on the same communication and scheduling framework."
//!
//! * **tokens** — the native idiom: operators hold/downgrade/drop
//!   [`crate::dataflow::TimestampToken`]s directly (nothing extra needed).
//! * [`notificator`] — Naiad-style notifications *as library operator
//!   logic* (§4: "we have implemented Naiad notifications in library
//!   operator logic"), including Naiad's unsorted pending list and
//!   one-notification-per-invocation contract.
//! * [`watermark`] — Flink-style watermarks: in-stream control records;
//!   each operator holds exactly one token per output, downgraded as its
//!   output watermark advances (§4: "operators that explicitly hold
//!   timestamp tokens for their output watermarks and downgrade them
//!   whenever these watermarks advance").

pub mod notificator;
pub mod watermark;

/// Which coordination mechanism a workload runs with (bench configuration).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mechanism {
    /// Timestamp tokens (the paper's contribution).
    Tokens,
    /// Naiad-style notifications.
    Notifications,
    /// Flink-style watermarks, cross-worker exchange at every stage
    /// (watermarks-X in §7.3).
    WatermarksX,
    /// Flink-style watermarks, worker-local pipelines (watermarks-P).
    WatermarksP,
}

impl Mechanism {
    /// All mechanisms, in the paper's reporting order.
    pub fn all() -> [Mechanism; 4] {
        [
            Mechanism::Tokens,
            Mechanism::Notifications,
            Mechanism::WatermarksX,
            Mechanism::WatermarksP,
        ]
    }

    /// The label used in tables and plots.
    pub fn label(&self) -> &'static str {
        match self {
            Mechanism::Tokens => "tokens",
            Mechanism::Notifications => "notifications",
            Mechanism::WatermarksX => "watermarks-X",
            Mechanism::WatermarksP => "watermarks-P",
        }
    }
}

impl std::str::FromStr for Mechanism {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "tokens" => Ok(Mechanism::Tokens),
            "notifications" => Ok(Mechanism::Notifications),
            "watermarks-x" | "watermarks-X" => Ok(Mechanism::WatermarksX),
            "watermarks-p" | "watermarks-P" => Ok(Mechanism::WatermarksP),
            other => Err(format!("unknown mechanism: {other}")),
        }
    }
}
