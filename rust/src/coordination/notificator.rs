//! Naiad-style notifications, implemented as library operator logic over
//! timestamp tokens (paper §4: "if in each invocation an operator processes
//! only their least timestamp they reproduce Naiad's notification
//! behavior").
//!
//! Two properties of Naiad are reproduced *faithfully* because they are the
//! source of the performance collapse the paper measures:
//!
//! 1. **Unsorted pending list** (§6.3: "a system like Naiad stores all
//!    events in an unsorted list and performs a sequential pass through
//!    this list in each scheduling round"): finding the next deliverable
//!    notification is a linear scan.
//! 2. **One notification per invocation** (§5.2: "the operator must
//!    repeatedly yield to the system and be reinvoked with advancing
//!    timestamps"): after delivering one completed timestamp, the
//!    notificator re-activates the operator and returns, so each retired
//!    timestamp costs a full system interaction.

use crate::dataflow::scope::Activator;
use crate::dataflow::token::TimestampToken;
use crate::progress::timestamp::{PartialOrder, Timestamp};

/// True iff some element of `frontier` is `<= t` (the timestamp may still
/// appear).
pub fn frontier_less_equal<T: Timestamp>(frontier: &[T], t: &T) -> bool {
    frontier.iter().any(|f| f.less_equal(t))
}

/// A Naiad-style notificator: owns the operator's retained tokens and
/// delivers "notifications" — completed timestamps — one at a time.
pub struct Notificator<T: Timestamp> {
    /// Unsorted pending notifications (deliberately; see module docs).
    pending: Vec<TimestampToken<T>>,
    activator: Activator,
}

impl<T: Timestamp> Notificator<T> {
    /// Creates a notificator for the operator with the given activator.
    pub fn new(activator: Activator) -> Self {
        Notificator { pending: Vec::new(), activator }
    }

    /// Requests a notification once all messages at or before the token's
    /// timestamp have been delivered. Duplicate requests for a timestamp
    /// coalesce (as in Naiad).
    pub fn notify_at(&mut self, token: TimestampToken<T>) {
        if !self.pending.iter().any(|t| t.time() == token.time()) {
            self.pending.push(token);
        }
    }

    /// Number of outstanding notification requests.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Delivers at most ONE completed notification: the least pending
    /// timestamp no longer permitted by `frontier`. If more completed
    /// notifications remain, the operator is re-activated so the system
    /// reinvokes it — Naiad's per-timestamp interaction.
    pub fn next(&mut self, frontier: &[T]) -> Option<TimestampToken<T>> {
        // Sequential pass over the unsorted list for the minimum completed
        // entry (faithful to Naiad's scheduling cost model).
        // Minimality in the container (`Ord`) order — an arbitrary linear
        // extension of the partial order, as used by Naiad's delivery.
        let mut best: Option<usize> = None;
        for (i, token) in self.pending.iter().enumerate() {
            if !frontier_less_equal(frontier, token.time()) {
                best = match best {
                    None => Some(i),
                    Some(j) if token.time() < self.pending[j].time() => Some(i),
                    Some(j) => Some(j),
                };
            }
        }
        let i = best?;
        let token = self.pending.swap_remove(i);
        // More completed notifications? Ask to be scheduled again rather
        // than draining them in this invocation.
        if self.pending.iter().any(|t| !frontier_less_equal(frontier, t.time())) {
            self.activator.activate();
        }
        Some(token)
    }

    /// Drains every completed notification through `logic` — *not* Naiad's
    /// contract; provided for tests that need to compare against the
    /// batched behavior tokens allow.
    pub fn for_each_batched<L: FnMut(TimestampToken<T>)>(
        &mut self,
        frontier: &[T],
        mut logic: L,
    ) {
        let mut i = 0;
        while i < self.pending.len() {
            if !frontier_less_equal(frontier, self.pending[i].time()) {
                logic(self.pending.swap_remove(i));
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::token::BookkeepingHandle;
    use crate::progress::location::Location;
    use std::cell::Cell;
    use std::rc::Rc;

    fn token(t: u64, b: &BookkeepingHandle<u64>) -> TimestampToken<u64> {
        TimestampToken::mint_preseeded(t, Location::source(0, 0), b.clone())
    }

    fn frontier(at: Option<u64>) -> Vec<u64> {
        at.into_iter().collect()
    }

    #[test]
    fn delivers_min_completed_one_at_a_time() {
        let b = BookkeepingHandle::new();
        let flag = Rc::new(Cell::new(false));
        let mut n = Notificator::new(Activator::new(flag.clone()));
        for t in [5u64, 2, 8, 3] {
            n.notify_at(token(t, &b));
        }
        let f = frontier(Some(6)); // 2, 3, 5 completed
        let got = n.next(&f).unwrap();
        assert_eq!(*got.time(), 2);
        // Re-activation requested: more completed notifications pending.
        assert!(flag.get());
        assert_eq!(*n.next(&f).unwrap().time(), 3);
        assert_eq!(*n.next(&f).unwrap().time(), 5);
        assert!(n.next(&f).is_none());
        assert_eq!(n.pending(), 1); // 8 still pending
        std::mem::forget(n); // tokens are preseeded fakes
    }

    #[test]
    fn duplicates_coalesce() {
        let b = BookkeepingHandle::new();
        let mut n = Notificator::new(Activator::new(Rc::new(Cell::new(false))));
        n.notify_at(token(4, &b));
        n.notify_at(token(4, &b));
        assert_eq!(n.pending(), 1);
        std::mem::forget(n);
    }

    #[test]
    fn nothing_delivered_under_frontier() {
        let b = BookkeepingHandle::new();
        let mut n = Notificator::new(Activator::new(Rc::new(Cell::new(false))));
        n.notify_at(token(4, &b));
        let f = frontier(Some(4)); // 4 still possible
        assert!(n.next(&f).is_none());
        // Closed frontier delivers everything.
        let f = frontier(None);
        assert_eq!(*n.next(&f).unwrap().time(), 4);
        std::mem::forget(n);
    }

    #[test]
    fn batched_drain_for_comparison() {
        let b = BookkeepingHandle::new();
        let mut n = Notificator::new(Activator::new(Rc::new(Cell::new(false))));
        for t in [1u64, 2, 3] {
            n.notify_at(token(t, &b));
        }
        let f = frontier(None);
        let mut got = Vec::new();
        n.for_each_batched(&f, |tok| {
            got.push(*tok.time());
            std::mem::forget(tok);
        });
        got.sort();
        assert_eq!(got, vec![1, 2, 3]);
        assert_eq!(n.pending(), 0);
    }
}
