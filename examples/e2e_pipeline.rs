//! End-to-end driver: the full three-layer system on a real small workload.
//!
//!     cargo run --release --example e2e_pipeline [workers] [seconds]
//!
//! Pipeline (per worker): an open-loop source replays a synthetic text
//! corpus at a constant rate with quantized-nanosecond timestamps →
//! exchange by word → rolling word count → tumbling 50 ms windowed
//! statistics whose batch aggregation runs on the **AOT-compiled
//! JAX/Pallas kernel via PJRT** (Layer 1/2), orchestrated by the
//! token-coordinated Rust engine (Layer 3). The run reports the paper's
//! headline metric — end-to-end completion latency (p50/p999/max) — plus
//! sustained throughput and the number of PJRT kernel executions,
//! demonstrating that all layers compose on the request path with Python
//! nowhere in sight.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::{Duration, Instant};
use timestamp_tokens::harness::histogram::{fmt_ns, LatencyHistogram};
use timestamp_tokens::operators::window::WindowBackend;
use timestamp_tokens::prelude::*;
use timestamp_tokens::runtime::XlaWindowBackend;

/// A tiny real corpus (public-domain snippets) replayed in a loop.
const CORPUS: &str = "it was the best of times it was the worst of times it was the age \
of wisdom it was the age of foolishness it was the epoch of belief it was the epoch of \
incredulity call me ishmael some years ago never mind how long precisely having little \
or no money in my purse and nothing particular to interest me on shore i thought i would \
sail about a little and see the watery part of the world";

fn main() {
    let workers: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let seconds: u64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(3);
    let rate_per_worker: u64 = 200_000; // words/s/worker
    let quantum_ns: u64 = 1 << 16; // 65.5 µs timestamps
    let window_ns: u64 = 50_000_000; // 50 ms stats windows

    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }

    // Hash the corpus words once; the sources replay ids.
    let words: Vec<u64> = CORPUS
        .split_whitespace()
        .map(|w| timestamp_tokens::operators::wordcount::fnv1a(w.as_bytes()))
        .collect();
    println!(
        "e2e: {workers} workers, {rate_per_worker} words/s/worker, quantum {}, window {}, {}s",
        fmt_ns(quantum_ns),
        fmt_ns(window_ns),
        seconds
    );

    let epoch = Instant::now() + Duration::from_millis(100);
    let results = execute::<u64, _, _>(
        Config { workers, ..Config::default() },
        move |worker| {
            let (mut input, stream) = worker.new_input::<u64>();

            // Stage 1: exchanged rolling word count (tokens, oblivious).
            let counted = stream.word_count();

            // Stage 2: windowed statistics over the counts, aggregated by
            // the PJRT data plane. Count per window + mean count value.
            let xla = Rc::new(RefCell::new(
                XlaWindowBackend::new("artifacts").expect("artifacts compiled"),
            ));
            let xla2 = xla.clone();
            let stats = Rc::new(RefCell::new(Vec::new()));
            let stats2 = stats.clone();
            let windowed = counted.unary_frontier(
                Pact::Pipeline,
                "window_stats_xla",
                move |tok, _info| {
                    drop(tok);
                    let mut windows: std::collections::BTreeMap<
                        u64,
                        (TimestampToken<u64>, Vec<(u64, u64)>),
                    > = std::collections::BTreeMap::new();
                    move |input: &mut _, output: &mut _| {
                        while let Some((token, data)) = input.next() {
                            let w = (*token.time() / window_ns + 1) * window_ns;
                            let entry = windows.entry(w).or_insert_with(|| {
                                let mut t = token.retain();
                                t.downgrade(&w);
                                (t, Vec::new())
                            });
                            entry.1.extend(data.iter().map(|&(_, c)| (w, c)));
                        }
                        let bound = input
                            .frontier()
                            .frontier()
                            .first()
                            .cloned()
                            .unwrap_or(u64::MAX);
                        let sealed: Vec<u64> =
                            windows.range(..bound).map(|(&w, _)| w).collect();
                        for w in sealed {
                            let (token, items) = windows.remove(&w).unwrap();
                            // Layer 1/2: segmented aggregation on PJRT.
                            let agg = xla2.borrow_mut().aggregate(&items);
                            let mut session = output.session(&token);
                            for (window, sum, count) in agg {
                                session.give((window, sum, count));
                            }
                        }
                    }
                },
            );
            let probe = windowed
                .inspect(move |_t, &(w, sum, count)| {
                    stats2.borrow_mut().push((w, sum, count));
                })
                .probe();

            // Open-loop source.
            let total_ns = seconds * 1_000_000_000;
            let mut histogram = LatencyHistogram::new();
            let mut pending: std::collections::VecDeque<u64> = Default::default();
            let mut sent = 0u64;
            let mut last_q = 0u64;
            let mut cursor = worker.index(); // stagger corpus positions
            while Instant::now() < epoch {
                std::thread::yield_now();
            }
            loop {
                let now = epoch.elapsed().as_nanos() as u64;
                if now >= total_ns {
                    break;
                }
                let q = now / quantum_ns * quantum_ns;
                if q > last_q {
                    input.advance_to(q);
                    last_q = q;
                    pending.push_back(q);
                }
                let target = (now as u128 * rate_per_worker as u128 / 1_000_000_000) as u64;
                while sent < target {
                    input.send(words[cursor % words.len()]);
                    cursor += 1;
                    sent += 1;
                }
                worker.step();
                let now2 = epoch.elapsed().as_nanos() as u64;
                while let Some(&oldest) = pending.front() {
                    if !probe.less_equal(&oldest) {
                        histogram.record(now2.saturating_sub(oldest));
                        pending.pop_front();
                    } else {
                        break;
                    }
                }
            }
            input.close();
            worker.step_while(|| !probe.done());
            let executions = xla.borrow().executions();
            let n_windows = stats.borrow().len();
            (histogram, sent, executions, n_windows)
        },
    );

    let mut merged = LatencyHistogram::new();
    let mut total_sent = 0;
    let mut total_exec = 0;
    let mut total_windows = 0;
    for (h, sent, executions, windows) in results {
        merged.merge(&h);
        total_sent += sent;
        total_exec += executions;
        total_windows += windows;
    }
    println!("throughput: {:.2} M words/s sustained", total_sent as f64 / seconds as f64 / 1e6);
    println!(
        "completion latency: p50 {}  p999 {}  max {}  ({} stamps)",
        fmt_ns(merged.p50()),
        fmt_ns(merged.p999()),
        fmt_ns(merged.max()),
        merged.count()
    );
    println!("PJRT kernel executions: {total_exec} (windows sealed: {total_windows})");
    assert!(merged.count() > 0, "no stamps completed");
    assert!(total_exec > 0, "the XLA data plane was never exercised");
    assert!(
        merged.max() < 1_000_000_000,
        "end-to-end latency exceeded the paper's 1 s DNF bound"
    );
    println!("e2e_pipeline OK");
}
