//! Quickstart: a multi-worker rolling word count coordinated by timestamp
//! tokens.
//!
//!     cargo run --release --example quickstart [workers]
//!
//! Demonstrates the full public API surface in ~40 lines: inputs, epochs,
//! an exchanged stateful operator, probes, and completion.

use std::cell::RefCell;
use std::rc::Rc;
use timestamp_tokens::prelude::*;

fn main() {
    let workers: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let corpus = [
        "timestamp tokens are a coordination primitive",
        "tokens grant the ability to produce timestamped data",
        "operators hold downgrade and drop tokens",
        "the system only sees net changes to token counts",
    ];

    let totals = execute::<u64, _, _>(Config::default_with_workers(workers), move |worker| {
        let (mut input, stream) = worker.new_input::<String>();
        let counts = Rc::new(RefCell::new(Vec::new()));
        let counts2 = counts.clone();
        let probe = stream
            .rolling_count()
            .inspect(move |t, (word, count)| {
                counts2.borrow_mut().push((*t, word.clone(), *count));
            })
            .probe();

        // Worker 0 plays one line per epoch; everyone else just runs.
        if worker.index() == 0 {
            for (epoch, line) in corpus.iter().enumerate() {
                input.advance_to(epoch as u64);
                for word in line.split_whitespace() {
                    input.send(word.to_string());
                }
            }
        }
        input.close();
        worker.step_while(|| !probe.done());
        let got = counts.borrow().clone();
        got
    });

    let mut all: Vec<_> = totals.into_iter().flatten().collect();
    all.sort();
    println!("observed {} (word, count) updates across workers", all.len());
    let mut finals = std::collections::BTreeMap::new();
    for (_t, word, count) in all {
        let slot = finals.entry(word).or_insert(0);
        *slot = (*slot).max(count);
    }
    println!("final counts:");
    for (word, count) in finals.iter().filter(|(_, &c)| c > 1) {
        println!("  {word:>12}: {count}");
    }
    assert_eq!(finals["tokens"], 3);
    println!("quickstart OK ({workers} workers)");
}
