//! Co-operative flow control (paper §6.1, the Faucet pattern).
//!
//!     cargo run --release --example flow_control
//!
//! A dataflow operator may produce unboundedly many outputs per input.
//! Under Naiad's model, returning from an invocation means "done"; with
//! timestamp tokens the operator can *yield control without yielding the
//! right to resume*: it emits up to a per-invocation budget, retains its
//! token, requests re-activation, and continues next time it is scheduled
//! — all in user code, with no engine support for flow control.

use std::cell::RefCell;
use std::rc::Rc;
use timestamp_tokens::prelude::*;

/// Per-input expansion factor: each input record requests this many
/// outputs.
const EXPANSION: u64 = 10_000;
/// Per-invocation output budget (the "faucet" aperture).
const BUDGET: u64 = 1_000;

fn main() {
    let (emitted, invocations) = execute_single::<u64, _, _>(|worker| {
        let (mut input, stream) = worker.new_input::<u64>();
        let invocations = Rc::new(RefCell::new(0u64));
        let invocations2 = invocations.clone();

        let expanded = stream.unary_frontier(
            Pact::Pipeline,
            "faucet",
            move |tok, info: OperatorInfo| {
                drop(tok);
                // Work queue: (token, remaining outputs to produce).
                let mut backlog: Vec<(TimestampToken<u64>, u64)> = Vec::new();
                let activator = info.activator.clone();
                move |input: &mut _, output: &mut _| {
                    *invocations2.borrow_mut() += 1;
                    // New inputs enqueue work, retaining the token.
                    while let Some((token, data)) = input.next() {
                        for seed in data {
                            backlog.push((token.retain(), seed * EXPANSION));
                        }
                    }
                    // Produce up to BUDGET outputs, then yield — keeping
                    // the tokens for the rest (this is the entire flow
                    // control mechanism).
                    let mut budget = BUDGET;
                    while budget > 0 {
                        match backlog.last_mut() {
                            None => break,
                            Some((token, remaining)) => {
                                let burst = budget.min(*remaining);
                                let mut session = output.session(&*token);
                                for i in 0..burst {
                                    session.give(*remaining - i);
                                }
                                *remaining -= burst;
                                budget -= burst;
                                if *remaining == 0 {
                                    drop(session);
                                    backlog.pop(); // token dropped here
                                }
                            }
                        }
                    }
                    if !backlog.is_empty() {
                        activator.activate(); // resume next scheduling
                    }
                }
            },
        );

        let count = Rc::new(RefCell::new(0u64));
        let count2 = count.clone();
        let probe = expanded.inspect(move |_, _| *count2.borrow_mut() += 1).probe();

        input.send(1);
        input.send(2);
        input.close();
        worker.step_while(|| !probe.done());
        let got = (*count.borrow(), *invocations.borrow());
        got
    });

    println!("emitted {emitted} records over {invocations} operator invocations");
    assert_eq!(emitted, 3 * EXPANSION);
    // The faucet must have yielded ~ (total / BUDGET) times, not once:
    assert!(
        invocations >= 3 * EXPANSION / BUDGET,
        "operator failed to yield between bursts"
    );
    println!(
        "flow_control OK: ≤{BUDGET} outputs per invocation, token retained across {} yields",
        invocations - 1
    );
}
