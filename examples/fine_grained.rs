//! Fine-grained timestamps (paper §6.2, the DD batching pattern).
//!
//!     cargo run --release --example fine_grained
//!
//! Events arrive with potentially *unique* nanosecond timestamps. Naiad
//! would force one system interaction per distinct timestamp; with tokens
//! the operator batches events into intervals itself: it retains only the
//! LEAST timestamp token for its un-batched events, seals batches as its
//! input frontier advances, and downgrades that one token — interacting
//! with the system at a granularity *it* chooses, independent of the
//! timestamp granularity.

use std::cell::RefCell;
use std::rc::Rc;
use timestamp_tokens::prelude::*;

fn main() {
    let (batches, system_updates) = execute_single::<u64, _, _>(|worker| {
        let (mut input, stream) = worker.new_input::<(u64, u64)>();
        let sealed = Rc::new(RefCell::new(Vec::new()));
        let sealed2 = sealed.clone();

        // The DD-style batcher: one held token, downgraded as the frontier
        // advances; emits (interval_end, batch_size) per sealed batch.
        let batched = stream.unary_frontier(
            Pact::Pipeline,
            "dd_batcher",
            move |tok, _info| {
                // Hold the initial token as "the least timestamp token for
                // the times of unbatched messages" (§6.2).
                let mut held: Option<TimestampToken<u64>> = Some(tok);
                let mut unbatched: Vec<(u64, u64)> = Vec::new();
                let mut downgrades = 0u64;
                move |input: &mut _, output: &mut _| {
                    while let Some((_token, data)) = input.next() {
                        // NB: per-event tokens are NOT retained — that is
                        // the whole point. Events buffer locally.
                        unbatched.extend(data);
                    }
                    let frontier_first =
                        input.frontier().frontier().first().cloned();
                    if let Some(token) = held.as_mut() {
                        match frontier_first {
                            Some(bound) if bound > *token.time() => {
                                // Seal everything below the new frontier
                                // into ONE batch, emitted at the token.
                                let ready: Vec<(u64, u64)> = {
                                    let (sealed, rest): (Vec<_>, Vec<_>) =
                                        unbatched.drain(..).partition(|(t, _)| *t < bound);
                                    unbatched = rest;
                                    sealed
                                };
                                if !ready.is_empty() {
                                    output
                                        .session(&*token)
                                        .give((bound, ready.len() as u64));
                                }
                                // ONE system interaction for the whole
                                // interval, however many distinct
                                // timestamps it contained.
                                token.downgrade(&bound);
                                downgrades += 1;
                            }
                            Some(_) => {}
                            None => {
                                // Input closed: seal the tail and release.
                                if !unbatched.is_empty() {
                                    output
                                        .session(&*token)
                                        .give((u64::MAX, unbatched.len() as u64));
                                    unbatched.clear();
                                }
                                let _ = downgrades;
                                held = None;
                            }
                        }
                    }
                }
            },
        );
        let probe = batched
            .inspect(move |_t, (bound, size)| sealed2.borrow_mut().push((*bound, *size)))
            .probe();

        // 10,000 events with unique ns timestamps, input advancing every
        // 1000 events (the input chooses ITS granularity too).
        let mut sent = 0u64;
        for burst in 0..10u64 {
            for i in 0..1000u64 {
                let ns = burst * 1_000_000 + i * 997; // unique ns stamps
                input.send((ns, i));
                sent += 1;
            }
            input.advance_to((burst + 1) * 1_000_000);
            // Let the frontier advance so the batcher seals per interval.
            for _ in 0..4 {
                worker.step();
            }
        }
        input.close();
        worker.step_while(|| !probe.done());
        let got = (sealed.borrow().clone(), sent);
        got
    });

    println!("sealed {} batches from {} unique-timestamp events:", batches.len(), system_updates);
    for (bound, size) in &batches {
        if *bound == u64::MAX {
            println!("  final batch: {size} events");
        } else {
            println!("  interval up to {bound:>9} ns: {size} events");
        }
    }
    let total: u64 = batches.iter().map(|(_, s)| s).sum();
    assert_eq!(total, 10_000, "every event lands in exactly one batch");
    assert!(
        batches.len() <= 11,
        "coordination happened per interval, not per distinct timestamp"
    );
    println!("fine_grained OK: 10000 distinct timestamps, {} batches", batches.len());
}
