//! The paper's §5 running example: the tumbling windowed average operator
//! (Figure 4/5), exercised on both aggregation backends.
//!
//!     cargo run --release --example windowed_average [native|xla]
//!
//! The operator reports the average of its input values every 10 time
//! units, at the timestamp of the start of the next window, and produces
//! no output for empty windows. With `xla` the per-batch accumulation runs
//! through the AOT-compiled JAX/Pallas segmented-aggregation kernel via
//! PJRT (`make artifacts` first).

use std::cell::RefCell;
use std::rc::Rc;
use timestamp_tokens::config::AggBackend;
use timestamp_tokens::operators::window::NativeWindowBackend;
use timestamp_tokens::prelude::*;
use timestamp_tokens::runtime::XlaWindowBackend;

fn main() {
    let backend: AggBackend = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("native|xla"))
        .unwrap_or(AggBackend::Native);

    // The data of the paper's Figure 4: values arriving across windows
    // [0,10), [10,20), [20,30) — with a gap in [30,40).
    let data: Vec<(u64, u64)> = vec![
        (1, 5),
        (4, 7),
        (9, 9),  // window [0,10): avg 7 at ts 10
        (12, 40),
        (17, 2), // window [10,20): avg 21 at ts 20
        (23, 8), // window [20,30): avg 8 at ts 30
        (41, 100), // window [40,50): avg 100 at ts 50
    ];

    let results = execute_single::<u64, _, _>(move |worker| {
        let (mut input, stream) = worker.new_input::<u64>();
        let out = Rc::new(RefCell::new(Vec::new()));
        let out2 = out.clone();
        let backend_box: Box<dyn timestamp_tokens::operators::window::WindowBackend> =
            match backend {
                AggBackend::Native => Box::new(NativeWindowBackend),
                AggBackend::Xla => Box::new(
                    XlaWindowBackend::new("artifacts")
                        .expect("run `make artifacts` before using the xla backend"),
                ),
            };
        let probe = stream.window_average(10, backend_box).probe_with(move |t, avgs| {
            for avg in avgs {
                out2.borrow_mut().push((*t, *avg));
            }
        });
        for (t, v) in data.clone() {
            input.advance_to(t);
            input.send(v);
        }
        input.close();
        worker.step_while(|| !probe.done());
        let got = out.borrow().clone();
        got
    });

    println!("windowed averages ({backend:?} backend):");
    for (t, avg) in &results {
        println!("  window closing at t={t:>3}: avg = {avg}");
    }
    assert_eq!(
        results,
        vec![(10, 7.0), (20, 21.0), (30, 8.0), (50, 100.0)],
        "averages must match the paper's semantics"
    );
    println!("windowed_average OK");
}
