"""Layer 2: the JAX compute graphs lowered to AOT artifacts.

Each entry in :data:`ARTIFACTS` is one fixed-shape computation built on the
Layer-1 Pallas kernel (``kernels.window_agg``), lowered once by ``aot.py``
to HLO text and executed from the Rust runtime via PJRT. Shapes are static
because PJRT executables are shape-specialized; the Rust side pads batches
to the artifact's batch size (negative ids = padding lanes).

Variants:
  * ``window_agg_{N}x{W}`` — full four-statistic aggregation used by the
    windowed-average operator and the e2e pipeline example.
  * ``window_max_{N}x{W}`` — max-only projection for NEXMark Q7's
    windowed-highest-bid (smaller module, faster execution).
"""

import jax
import jax.numpy as jnp

from .kernels.window_agg import window_agg


def make_window_agg(n, w, block_n=256):
    """Full aggregation: (values f32[n], ids i32[n]) -> 4 x f32[w]."""

    def fn(values, ids):
        return window_agg(values, ids, n_windows=w, block_n=min(block_n, n))

    args = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )
    return fn, args


def make_window_max(n, w, block_n=256):
    """Max-only aggregation: (values f32[n], ids i32[n]) -> (maxs, counts)."""

    def fn(values, ids):
        sums, counts, maxs, _mins = window_agg(
            values, ids, n_windows=w, block_n=min(block_n, n)
        )
        del sums
        return maxs, counts

    args = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )
    return fn, args


# name -> (builder, metadata). Metadata is copied into the manifest that the
# Rust runtime reads (artifacts/manifest.txt).
ARTIFACTS = {
    "window_agg_1024x64": {
        "build": lambda: make_window_agg(1024, 64),
        "n": 1024,
        "w": 64,
        "outputs": 4,
    },
    "window_agg_256x16": {
        "build": lambda: make_window_agg(256, 16),
        "n": 256,
        "w": 16,
        "outputs": 4,
    },
    "window_max_1024x64": {
        "build": lambda: make_window_max(1024, 64),
        "n": 1024,
        "w": 64,
        "outputs": 2,
    },
}
