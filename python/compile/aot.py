"""AOT bridge: lower every Layer-2 computation to HLO text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/load_hlo/.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
Build-time only: the Rust request path never invokes Python.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", default=None, help="lower a single artifact")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, spec in sorted(ARTIFACTS.items()):
        if args.only and name != args.only:
            continue
        fn, example_args = spec["build"]()
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(
            f"{name} n={spec['n']} w={spec['w']} outputs={spec['outputs']} file={name}.hlo.txt"
        )
        print(f"wrote {len(text)} chars to {path}")

    # Plain-text manifest (the Rust side has no JSON dependency).
    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(manifest_lines)} entries to {manifest}")


if __name__ == "__main__":
    main()
