"""Layer 1: the segmented window-aggregation Pallas kernel.

The data-plane hot spot of the reproduction's workloads (the §5 windowed
average, the §7.2 word-count tallies, NEXMark Q4/Q7 window maxima) is a
*segmented reduction*: fold a batch of ``(window_id, value)`` pairs into
per-window statistics. On GPUs this is idiomatically a scatter-add with
atomics into shared memory; TPUs have neither. The kernel therefore
reformulates the reduction as a **one-hot matmul** so the sum/count land on
the MXU systolic array, with the max/min handled by masked VPU reductions
(see DESIGN.md §Hardware-Adaptation):

    onehot[N, W] = (ids[:, None] == arange(W)) & (ids >= 0)
    sums   = onehot^T @ values          # MXU
    counts = onehot^T @ ones            # MXU
    maxs   = max_n where(onehot, v, -inf)   # VPU
    mins   = min_n where(onehot, v, +inf)   # VPU

The grid walks the batch dimension in ``block_n`` chunks, accumulating into
the full ``[W]`` outputs, so arbitrarily large batches stream through a
fixed VMEM footprint (block_n * (W + 2) * 4 bytes of live values).

Negative ids mark padding lanes and contribute to nothing.

The kernel is always lowered with ``interpret=True``: real TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute; interpret
mode lowers to plain HLO with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sentinel initial values for the max/min accumulators (plain Python floats
# so the kernel does not capture tracer constants). Finite (rather than
# +-inf) so that empty windows produce well-defined artifacts; the Rust side
# treats windows with count == 0 as empty and ignores their max/min lanes.
MAX_INIT = -3.0e38
MIN_INIT = 3.0e38


def _window_agg_kernel(values_ref, ids_ref, sums_ref, counts_ref, maxs_ref, mins_ref, *, n_windows):
    """One grid step: fold a block of (value, id) lanes into the accumulators."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        maxs_ref[...] = jnp.full_like(maxs_ref, MAX_INIT)
        mins_ref[...] = jnp.full_like(mins_ref, MIN_INIT)

    values = values_ref[...]  # [block_n] f32
    ids = ids_ref[...]  # [block_n] i32
    valid = ids >= 0
    # One-hot routing matrix: [block_n, W]. The equality broadcast is cheap
    # on the VPU; the transposed matmuls below are the MXU work.
    onehot_bool = (ids[:, None] == jnp.arange(n_windows, dtype=jnp.int32)[None, :]) & valid[:, None]
    onehot = onehot_bool.astype(jnp.float32)

    sums_ref[...] += onehot.T @ values
    counts_ref[...] += jnp.sum(onehot, axis=0)

    masked_max = jnp.where(onehot_bool, values[:, None], MAX_INIT)
    maxs_ref[...] = jnp.maximum(maxs_ref[...], jnp.max(masked_max, axis=0))
    masked_min = jnp.where(onehot_bool, values[:, None], MIN_INIT)
    mins_ref[...] = jnp.minimum(mins_ref[...], jnp.min(masked_min, axis=0))


@functools.partial(jax.jit, static_argnames=("n_windows", "block_n"))
def window_agg(values, ids, *, n_windows, block_n=256):
    """Segmented per-window aggregation.

    Args:
      values: ``f32[N]`` batch of values (padding lanes arbitrary).
      ids: ``i32[N]`` window slot per lane, ``-1`` (any negative) = padding.
      n_windows: number of window slots ``W``.
      block_n: grid block along the batch dimension.

    Returns:
      ``(sums f32[W], counts f32[W], maxs f32[W], mins f32[W])``.
    """
    n = values.shape[0]
    assert n % block_n == 0, f"N={n} must be a multiple of block_n={block_n}"
    grid = (n // block_n,)
    out_shape = [jax.ShapeDtypeStruct((n_windows,), jnp.float32) for _ in range(4)]
    kernel = functools.partial(_window_agg_kernel, n_windows=n_windows)
    out_spec = pl.BlockSpec((n_windows,), lambda i: (0,))
    sums, counts, maxs, mins = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[out_spec, out_spec, out_spec, out_spec],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(values, ids)
    return sums, counts, maxs, mins
