"""Pure-jnp correctness oracle for the window-aggregation kernel.

This is the reference semantics the Pallas kernel (and therefore the AOT
artifact the Rust runtime executes) must match. pytest asserts allclose
between :func:`window_agg_ref` and ``window_agg.window_agg`` across
hypothesis-generated shapes, dtypes-in-range, and value distributions.
"""

import jax.numpy as jnp

from .window_agg import MAX_INIT, MIN_INIT


def window_agg_ref(values, ids, *, n_windows):
    """Reference segmented aggregation.

    Same contract as ``window_agg.window_agg``: negative ids are padding;
    outputs are ``(sums, counts, maxs, mins)``, each ``f32[n_windows]``,
    with empty windows reporting sum 0, count 0, max MAX_INIT, min MIN_INIT.
    """
    values = jnp.asarray(values, dtype=jnp.float32)
    ids = jnp.asarray(ids, dtype=jnp.int32)
    valid = ids >= 0
    onehot = (ids[:, None] == jnp.arange(n_windows, dtype=jnp.int32)[None, :]) & valid[:, None]

    sums = jnp.sum(jnp.where(onehot, values[:, None], 0.0), axis=0)
    counts = jnp.sum(onehot.astype(jnp.float32), axis=0)
    maxs = jnp.max(jnp.where(onehot, values[:, None], MAX_INIT), axis=0)
    mins = jnp.min(jnp.where(onehot, values[:, None], MIN_INIT), axis=0)
    return sums, counts, maxs, mins
