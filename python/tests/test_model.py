"""Layer-2 checks: artifact registry shapes and AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import to_hlo_text
from compile.model import ARTIFACTS, make_window_agg, make_window_max


@pytest.mark.parametrize("name", sorted(ARTIFACTS))
def test_artifact_builds_and_lowers(name):
    spec = ARTIFACTS[name]
    fn, args = spec["build"]()
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    assert "ENTRY" in text, "expected HLO text with an entry computation"
    # The entry returns a tuple with the declared number of outputs.
    assert text.count("f32[%d]" % spec["w"]) >= spec["outputs"]


def test_window_agg_outputs_match_registry_shapes():
    fn, _ = make_window_agg(256, 16)
    values = jnp.zeros(256, jnp.float32)
    ids = jnp.zeros(256, jnp.int32)
    outs = fn(values, ids)
    assert len(outs) == 4
    for o in outs:
        assert o.shape == (16,)


def test_window_max_is_projection_of_full_agg():
    rng = np.random.default_rng(7)
    values = jnp.asarray(rng.normal(size=1024), jnp.float32)
    ids = jnp.asarray(rng.integers(-1, 64, size=1024), jnp.int32)
    full_fn, _ = make_window_agg(1024, 64)
    max_fn, _ = make_window_max(1024, 64)
    _, counts_full, maxs_full, _ = full_fn(values, ids)
    maxs, counts = max_fn(values, ids)
    np.testing.assert_allclose(np.asarray(maxs), np.asarray(maxs_full))
    np.testing.assert_allclose(np.asarray(counts), np.asarray(counts_full))
