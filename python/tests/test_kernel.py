"""Layer-1 correctness: the Pallas kernel vs the pure-jnp oracle.

This is the core correctness signal for the AOT data plane: the artifact
the Rust runtime executes is the lowered form of exactly the function under
test here. Hypothesis sweeps batch sizes, window counts, block sizes, id
distributions (including all-padding), and value ranges.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import window_agg_ref
from compile.kernels.window_agg import MAX_INIT, MIN_INIT, window_agg


def assert_matches_ref(values, ids, n_windows, block_n):
    got = window_agg(
        jnp.asarray(values, jnp.float32),
        jnp.asarray(ids, jnp.int32),
        n_windows=n_windows,
        block_n=block_n,
    )
    want = window_agg_ref(values, ids, n_windows=n_windows)
    for g, w, name in zip(got, want, ["sums", "counts", "maxs", "mins"]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-4, err_msg=name
        )


def test_simple_two_windows():
    values = [1.0, 2.0, 3.0, 4.0]
    ids = [0, 1, 0, 1]
    sums, counts, maxs, mins = window_agg(
        jnp.asarray(values, jnp.float32),
        jnp.asarray(ids, jnp.int32),
        n_windows=2,
        block_n=4,
    )
    np.testing.assert_allclose(np.asarray(sums), [4.0, 6.0])
    np.testing.assert_allclose(np.asarray(counts), [2.0, 2.0])
    np.testing.assert_allclose(np.asarray(maxs), [3.0, 4.0])
    np.testing.assert_allclose(np.asarray(mins), [1.0, 2.0])


def test_padding_lanes_ignored():
    values = [5.0, 100.0, 7.0, -100.0]
    ids = [0, -1, 0, -1]
    sums, counts, maxs, mins = window_agg(
        jnp.asarray(values, jnp.float32),
        jnp.asarray(ids, jnp.int32),
        n_windows=1,
        block_n=4,
    )
    assert float(sums[0]) == 12.0
    assert float(counts[0]) == 2.0
    assert float(maxs[0]) == 7.0
    assert float(mins[0]) == 5.0


def test_empty_windows_report_sentinels():
    values = [1.0] * 4
    ids = [0] * 4
    sums, counts, maxs, mins = window_agg(
        jnp.asarray(values, jnp.float32),
        jnp.asarray(ids, jnp.int32),
        n_windows=3,
        block_n=4,
    )
    assert float(counts[1]) == 0.0 and float(counts[2]) == 0.0
    assert float(maxs[1]) == pytest.approx(float(MAX_INIT))
    assert float(mins[2]) == pytest.approx(float(MIN_INIT))


def test_accumulates_across_grid_blocks():
    # N = 512 with block_n = 128: 4 grid steps must accumulate.
    rng = np.random.default_rng(0)
    values = rng.normal(size=512).astype(np.float32)
    ids = rng.integers(0, 8, size=512).astype(np.int32)
    assert_matches_ref(values, ids, n_windows=8, block_n=128)


@settings(max_examples=40, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    block_n=st.sampled_from([8, 32, 128]),
    n_windows=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
    padding_frac=st.floats(0.0, 1.0),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_hypothesis_matches_ref(n_blocks, block_n, n_windows, seed, padding_frac, scale):
    n = n_blocks * block_n
    rng = np.random.default_rng(seed)
    values = (rng.normal(size=n) * scale).astype(np.float32)
    ids = rng.integers(0, n_windows, size=n).astype(np.int32)
    pad = rng.random(size=n) < padding_frac
    ids = np.where(pad, -1, ids).astype(np.int32)
    assert_matches_ref(values, ids, n_windows=n_windows, block_n=block_n)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_all_padding_batch(seed):
    rng = np.random.default_rng(seed)
    values = rng.normal(size=64).astype(np.float32)
    ids = np.full(64, -1, dtype=np.int32)
    assert_matches_ref(values, ids, n_windows=4, block_n=32)
